"""Sobol' sequences + QMC cubature (QMCPy's CubQMCSobolG analogue, §4.2).

Direction numbers: new-joe-kuo-6 table (Joe & Kuo 2008), first 21 dimensions
(enough for the paper's applications: 3-d defect UQ, 16-d L2-Sea inputs).
Randomization: digital (XOR) scrambling; replications give the CI used by
the doubling cubature.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# (s, a, [m_1..m_s]) for dimensions 2..21 (dim 1 uses the van der Corput base-2
# sequence). Source: new-joe-kuo-6.21201, Joe & Kuo (2008).
_JOE_KUO = [
    (1, 0, [1]),
    (2, 1, [1, 3]),
    (3, 1, [1, 3, 1]),
    (3, 2, [1, 1, 1]),
    (4, 1, [1, 1, 3, 3]),
    (4, 4, [1, 3, 5, 13]),
    (5, 2, [1, 1, 5, 5, 17]),
    (5, 4, [1, 1, 5, 5, 5]),
    (5, 7, [1, 1, 7, 11, 19]),
    (5, 11, [1, 1, 5, 1, 1]),
    (5, 13, [1, 1, 1, 3, 11]),
    (5, 14, [1, 3, 5, 5, 31]),
    (6, 1, [1, 3, 3, 9, 7, 49]),
    (6, 13, [1, 1, 1, 15, 21, 21]),
    (6, 16, [1, 3, 1, 13, 27, 49]),
    (6, 19, [1, 1, 1, 15, 7, 5]),
    (6, 22, [1, 3, 1, 15, 13, 25]),
    (6, 25, [1, 1, 5, 5, 19, 61]),
    (7, 1, [1, 3, 7, 11, 23, 15, 103]),
    (7, 4, [1, 3, 7, 13, 13, 15, 69]),
]

MAX_DIM = len(_JOE_KUO) + 1
_NBITS = 30


def _direction_numbers(dim: int) -> np.ndarray:
    """V[dim, _NBITS] direction integers (scaled by 2^_NBITS)."""
    assert 1 <= dim <= MAX_DIM, f"sobol dims <= {MAX_DIM}"
    V = np.zeros((dim, _NBITS), dtype=np.int64)
    # first dimension: van der Corput
    for i in range(_NBITS):
        V[0, i] = 1 << (_NBITS - 1 - i)
    for d in range(1, dim):
        s, a, m = _JOE_KUO[d - 1]
        m = list(m)
        for i in range(min(s, _NBITS)):
            V[d, i] = m[i] << (_NBITS - 1 - i)
        for i in range(s, _NBITS):
            v = V[d, i - s] ^ (V[d, i - s] >> s)
            for k in range(1, s):
                if (a >> (s - 1 - k)) & 1:
                    v ^= V[d, i - k]
            V[d, i] = v
    return V


def sobol(n: int, dim: int, scramble_seed: int | None = None, skip: int = 0) -> np.ndarray:
    """First n points (after `skip`) of the Sobol' sequence in [0,1)^dim.
    Gray-code order; optional digital scramble (XOR with a random shift)."""
    V = _direction_numbers(dim)
    total = n + skip
    x = np.zeros(dim, dtype=np.int64)
    out = np.empty((total, dim), dtype=np.int64)
    for i in range(total):
        out[i] = x
        c = (~np.uint64(i) & np.uint64(i + 1)).item().bit_length() - 1  # rightmost zero bit of i
        c = min(c, _NBITS - 1)
        x = x ^ V[:, c]
    pts = out[skip:]
    if scramble_seed is not None:
        rng = np.random.default_rng(scramble_seed)
        shift = rng.integers(0, 1 << _NBITS, size=dim, dtype=np.int64)
        pts = pts ^ shift
    return (pts.astype(np.float64) + 0.5 * (scramble_seed is None)) / (1 << _NBITS)


@dataclass
class CubatureResult:
    mean: np.ndarray
    std_error: np.ndarray
    n_evals: int
    converged: bool
    history: list


def _as_batched(f, config: dict | None):
    """Accept an `EvaluationFabric` (or anything exposing `evaluate_batch`)
    wherever a bare batched callable was accepted."""
    if hasattr(f, "evaluate_batch"):
        return lambda X: f.evaluate_batch(X, config)
    return f


def cub_qmc_sobol(
    f,
    dim: int,
    abs_tol: float = 1e-3,
    n_init: int = 64,
    n_max: int = 2**16,
    replications: int = 8,
    seed: int = 7,
    config: dict | None = None,
) -> CubatureResult:
    """Doubling Sobol' cubature of E[f(U)] with replicated scrambles
    (CubQMCSobolG-style): doubles N until the replication CI < abs_tol.
    `f` maps [N, dim] -> [N, m] (batched — a callable, pool or
    `EvaluationFabric`; `config` is forwarded to a fabric).

    Each doubling evaluates ONLY the new half of every replication (the
    Sobol' sequence is extended via `skip` and the per-replication sums are
    reused) — model evaluations are the expensive resource, and recomputing
    the first n points on every doubling would exactly double their count.

    The stopping rule is the CI across replication means, so at least two
    replications are required: with one, the ddof=1 std is NaN and the
    driver would silently burn evaluations all the way to `n_max` with
    `se=NaN` in the result. Rejected up front instead.
    """
    if replications < 2:
        raise ValueError(
            f"replications must be >= 2 (got {replications}): the stopping "
            "criterion is the standard error ACROSS replication means"
        )
    eval_fn = _as_batched(f, config)
    n = n_init
    n_done = 0  # points already evaluated per replication
    sums = None  # [R, m] running sum of f over each replication's points
    history = []
    while True:
        for r in range(replications):
            u = sobol(n - n_done, dim, scramble_seed=seed + r, skip=n_done)
            y = np.atleast_2d(np.asarray(eval_fn(u)))
            # eval_fn contract is [N, dim] -> [N, m]. np.atleast_2d turns an
            # m-output 1-D return for a single point into [1, m] and a
            # scalar-output [N] return into [1, N]; only that second,
            # unambiguous case is transposed. Anything else is a genuine
            # contract violation — raising beats silently mangling outputs
            # (the old `if rows != N: y = y.T` heuristic flipped [N, m]
            # results whenever it happened that m == N).
            n_new = n - n_done
            if y.shape[0] != n_new:
                if y.shape == (1, n_new):
                    y = y.T
                else:
                    raise ValueError(
                        f"eval_fn returned shape {y.shape} for {n_new} "
                        f"points; expected [{n_new}, m]"
                    )
            if sums is None:
                sums = np.zeros((replications, y.shape[1]))
            sums[r] += y.sum(axis=0)
        n_done = n
        vals = sums / n  # [R, m] replication means
        mean = vals.mean(axis=0)
        se = vals.std(axis=0, ddof=1) / np.sqrt(replications)
        history.append((n * replications, mean.copy(), se.copy()))
        if np.all(se * 2.58 < abs_tol):  # 99% CI
            return CubatureResult(mean, se, n * replications, True, history)
        if n * 2 > n_max:
            return CubatureResult(mean, se, n * replications, False, history)
        n *= 2
