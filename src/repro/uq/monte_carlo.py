"""Plain Monte Carlo estimation through a (pooled) model."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class MCResult:
    mean: np.ndarray
    std: np.ndarray
    std_error: np.ndarray
    n: int
    samples: np.ndarray


def monte_carlo(f, sampler, n: int, rng: np.random.Generator | None = None, batch: int = 0) -> MCResult:
    """f: [N,d] -> [N,m] batched model (e.g. ModelPool); sampler(rng, n) -> [n,d]."""
    rng = rng or np.random.default_rng(0)
    thetas = np.atleast_2d(sampler(rng, n))
    if batch:
        outs = [np.atleast_2d(f(thetas[i : i + batch])) for i in range(0, n, batch)]
        ys = np.concatenate(outs, axis=0)
    else:
        ys = np.atleast_2d(np.asarray(f(thetas)))
    if ys.shape[0] != n:
        ys = ys.T
    return MCResult(
        ys.mean(axis=0), ys.std(axis=0, ddof=1), ys.std(axis=0, ddof=1) / np.sqrt(n), n, ys
    )
