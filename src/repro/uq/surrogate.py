"""Surrogate-accelerated delayed acceptance: the level-(-1) screen.

The paper's MLDA application (§4.3) spends most of its wall-clock on
coarse-level subchain evaluations — exactly where a cheap surrogate screen
buys the most. This module provides the two pieces that turn the
`uq.gp.OnlineGP` emulator into a screen IN FRONT of the coarse model:

* `SurrogateStore` — a fabric training tap: it subscribes to an
  `EvaluationFabric`'s completed-wave traffic (`fabric.record_observer`)
  and streams each freshly computed (theta, output) row — mapped through a
  scalar `target(theta, y)` such as the log-likelihood — into the GP's
  sliding window. The surrogate therefore trains entirely from evaluations
  the sampler already paid for: ZERO extra model evaluations, each wave
  observed exactly once (cache hits are never replayed).

* `SurrogateScreen` — the first stage of three-stage delayed acceptance in
  `ensemble_mlda(surrogate=...)`: one lockstep `predict_batch` per step
  (zero fabric waves) scores every chain's proposal, only survivors pay
  the real coarse wave, and the stage-2 correction divides the coarse
  Metropolis ratio by the SAME screen ratio — so each step targets the
  coarse posterior EXACTLY for ANY screen (Christen & Fox 2005), including
  an arbitrarily wrong GP. The screen changes how many coarse evaluations
  are spent, never what an individual step accepts; for the chain-level
  guarantee, `freeze()` the screen after warm-up (an unfrozen screen is
  adaptive MCMC — see `SurrogateScreen`).
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.analysis.races import named_lock
from repro.core.protocol import config_key
from repro.uq.gp import OnlineGP

#: pass as `config=` to ingest waves under EVERY config (the default is to
#: ingest exactly the one config given — a fine-level wave must never train
#: a coarse-level surrogate)
ANY_CONFIG = object()


class SurrogateStore:
    """Fabric training tap -> sliding-window GP training set.

    `fabric.record_observer(store.observe)` wires it up; thereafter every
    completed wave whose op carries fresh forward values ("evaluate", or
    the value half of a fused "value_and_gradient" wave) and whose config
    matches `config` streams into the `OnlineGP` as
    (theta, target(theta, output)) pairs. Non-matching waves are ignored,
    matching waves are ingested exactly once, and the store never issues a
    model evaluation of its own.
    """

    def __init__(
        self,
        target: Callable[[np.ndarray, np.ndarray], float],
        config: dict | None = None,
        *,
        gp: OnlineGP | None = None,
        ops: Sequence[str] = ("evaluate", "value_and_gradient"),
        **gp_kwargs,
    ):
        self.target = target
        self.gp = gp if gp is not None else OnlineGP(**gp_kwargs)
        self.ops = tuple(ops)
        self._any = config is ANY_CONFIG
        self._cfg_key = None if self._any else config_key(config)
        self.n_waves = 0
        self.n_points = 0
        self._lock = named_lock("surrogate_store")

    def observe(self, op: str, thetas, outputs, config) -> None:
        """`record_observer` callback: one call per completed wave."""
        if op not in self.ops:
            return
        if not self._any and config_key(config) != self._cfg_key:
            return
        thetas = np.atleast_2d(np.asarray(thetas, float))
        outputs = np.atleast_2d(np.asarray(outputs, float))
        ts = np.asarray(
            [float(self.target(t, y)) for t, y in zip(thetas, outputs)]
        )
        with self._lock:
            self.n_waves += 1
            self.n_points += len(ts)
        self.gp.add(thetas, ts)

    def stats(self) -> dict:
        with self._lock:
            return {"waves_observed": self.n_waves, "points_observed": self.n_points}


class SurrogateScreen:
    """Level-(-1) GP screen for three-stage delayed acceptance.

    With g(theta) = gp_mean(theta) + logprior(theta), stage 1 promotes a
    proposal y from x with probability min{1, e^(g(y)-g(x))} at ZERO model
    cost; stage 2 (run by the sampler on survivors only) accepts with
    min{1, e^((lp(y)-lp(x)) - (g(y)-g(x)))} — the DA correction that makes
    the compound kernel exact for any g. Where the screen is skipped the
    log-ratio is 0, so the step degrades to plain lockstep Metropolis.

    Policy knobs (the staleness policy itself lives on the `OnlineGP`):

      * ``min_train`` (via the GP): the screen reports ``active = False``
        and skips every chain until the window holds enough traffic;
      * ``sd_skip``: the variance gate — a chain whose current state OR
        proposal has predictive sd above the gate skips the screen for
        that step, so the GP is never trusted where it is uncertain. The
        skip decision is symmetric in (x, y), preserving detailed balance;
      * ``freeze()``: stop ingesting/refitting. Each step's DA correction
        is exact regardless, but an UNFROZEN screen keeps adapting to the
        chain's own history — adaptive MCMC, whose chain-level guarantees
        need the adaptation to diminish (the sliding window saturating).
        Freezing after warm-up makes the kernel time-homogeneous and
        restores the standard ergodicity argument; do it before any run
        whose samples you keep.

    When `fabric` is given (e.g. via `from_fabric`), screen traffic is
    mirrored into the fabric telemetry (`surrogate_screened`,
    `screen_pass_rate`).
    """

    def __init__(
        self,
        source: SurrogateStore | OnlineGP,
        *,
        logprior: Callable[[np.ndarray], float] | None = None,
        sd_skip: float | None = None,
        fabric=None,
    ):
        if isinstance(source, SurrogateStore):
            self.store: SurrogateStore | None = source
            self.gp = source.gp
        elif isinstance(source, OnlineGP):
            self.store = None
            self.gp = source
        else:
            raise TypeError(
                "SurrogateScreen needs a SurrogateStore or an OnlineGP; "
                f"got {type(source).__name__}"
            )
        self.logprior = logprior
        self.sd_skip = None if sd_skip is None else float(sd_skip)
        self._fabric = fabric
        self.n_screened = 0
        self.n_passed = 0
        self.n_skipped = 0

    @classmethod
    def from_fabric(
        cls,
        fabric,
        target: Callable[[np.ndarray, np.ndarray], float],
        config: dict | None = None,
        *,
        logprior: Callable | None = None,
        sd_skip: float | None = None,
        gp: OnlineGP | None = None,
        **gp_kwargs,
    ) -> "SurrogateScreen":
        """Build the store, subscribe it to the fabric's training tap, and
        return the screen — one call wires the whole level-(-1) path:

            screen = SurrogateScreen.from_fabric(
                fabric, target=lambda th, y: loglik(y),
                config={"level": 0}, logprior=logprior,
                window=256, min_train=32)
            warm = ensemble_mlda(..., fabric=fabric, surrogate=screen)
            screen.freeze()  # stop adapting before the samples you keep
            res = ensemble_mlda(..., fabric=fabric, surrogate=screen)
        """
        store = SurrogateStore(target, config=config, gp=gp, **gp_kwargs)
        fabric.record_observer(store.observe)
        return cls(store, logprior=logprior, sd_skip=sd_skip, fabric=fabric)

    @property
    def active(self) -> bool:
        """Whether the GP has enough traffic to screen at all."""
        return self.gp.ready

    def freeze(self) -> None:
        self.gp.freeze()

    def delta(self, xs: np.ndarray, props: np.ndarray):
        """Screen log-ratio g(prop) - g(x) per chain plus the skip mask:
        ([K, d], [K, d]) -> (dg [K], skipped [K] bool), with dg = 0 where
        skipped (inactive screen, or variance gate). ONE lockstep
        `predict_batch` over both endpoints — zero fabric waves."""
        xs = np.atleast_2d(np.asarray(xs, float))
        props = np.atleast_2d(np.asarray(props, float))
        K = len(props)
        if not self.active:
            self.n_skipped += K
            return np.zeros(K), np.ones(K, bool)
        # the variance back-substitution is only paid when a gate consumes it
        gated = self.sd_skip is not None
        pred = self.gp.predict_batch(
            np.concatenate([xs, props], axis=0), return_var=gated
        )
        mu = pred[0] if gated else pred
        dg = np.asarray(mu[K:] - mu[:K], float)
        skipped = np.zeros(K, bool)
        if gated:
            sd = np.sqrt(pred[1])
            skipped = (sd[:K] > self.sd_skip) | (sd[K:] > self.sd_skip)
        if self.logprior is not None:
            pr_x = np.asarray([float(self.logprior(t)) for t in xs])
            pr_p = np.asarray([float(self.logprior(t)) for t in props])
            # a chain whose CURRENT state sits outside the support cannot
            # be screened: dg would be +inf and the stage-2 correction
            # would pin the chain there forever. Skip it — the step
            # degrades to plain Metropolis and the chain escapes; out-of-
            # support states are transient (never re-entered), so the skip
            # cannot affect stationarity.
            bad_x = ~np.isfinite(pr_x)
            skipped = skipped | bad_x
            with np.errstate(invalid="ignore"):
                dpr = pr_p - pr_x
            dg = dg + np.where(bad_x, 0.0, dpr)
        dg = np.where(skipped, 0.0, dg)
        self.n_skipped += int(skipped.sum())
        return dg, skipped

    def note(self, screened: int, passed: int) -> None:
        """Sampler-side telemetry callback: of `screened` actively screened
        proposals this step, `passed` survived stage 1. Mirrored into the
        fabric stats when fabric-attached."""
        self.n_screened += int(screened)
        self.n_passed += int(passed)
        if self._fabric is not None and screened:
            self._fabric.note_screen(screened, passed)

    def stats(self) -> dict:
        scr = self.n_screened
        out = {
            "screened": scr,
            "passed": self.n_passed,
            "pass_rate": (self.n_passed / scr) if scr else None,
            "skipped": self.n_skipped,
            "gp": self.gp.stats(),
        }
        if self.store is not None:
            out["store"] = self.store.stats()
        return out
