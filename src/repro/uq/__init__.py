# UQ method namespace; submodules imported directly (repro.uq.qmc, etc.)
