"""Device-resident fused sampler blocks (ROADMAP item 3).

The host lockstep samplers in `uq.mcmc` made waves WIDE: one `[K, d]`
model wave per MCMC step instead of K single-point calls. But the hot loop
still pays one dispatch — and one full device round trip — per step, so on
a fast posterior the sampler is latency-bound at the driver/solver boundary
(exactly where QUEENS/UQpy-style frameworks stop). This module makes waves
DEEP as well: S sampler steps are fused into ONE jitted `jax.lax.scan`
block with

* on-device proposal generation — a `jax.random` key stream threaded
  through the scan carry (split per step, never reused),
* log-posterior evaluation through the model's native JAX batch path
  (any traceable ``[K, d] -> [K]`` callable; see the target builders),
* Metropolis accept/reject, and Robbins-Monro step-size adaptation for
  MALA, all inside the block,

so only every S-th state crosses the host boundary. The ``[K, d]`` chain
block and ``[K]`` log-density carry are sharded over the ctx mesh with the
same ``in_shardings`` / pow2-bucketing discipline the evaluate path uses
(`core.pool.ModelPool._dispatch_fn`), and the per-step-dispatch reference
path is the SAME compiled S=1 block driven from a host loop — which makes
the S=1 bit-exactness invariant (CONTRIBUTING) hold by construction and
keeps the fused-vs-per-step benchmark an apples-to-apples dispatch-cost
measurement.

Checkpointing reconciles with `core.fleet.CampaignCheckpoint` at block
boundaries: the carry arrays land as npy leaves and the PRNG key rides as
its raw key-data manifest (`CampaignCheckpoint.pack_key`), so a killed
campaign resumed with the same block size replays the identical key stream
— bit-exact, not just statistically indistinguishable.

The host numpy loops in `uq.mcmc` remain the reference implementation and
the only path for non-JAX backends (HTTP models, subprocess fleets); the
`ensemble_*` entry points there expose this module as ``fused_steps=S``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interface import next_pow2, pad_to_bucket
from repro.uq.mcmc import EnsembleResult

#: compile-once memo for (step closure, jitted block) pairs: the public
#: runners are called repeatedly in campaigns/benchmarks, and a fresh step
#: closure per call would defeat the jit cache and recompile the whole
#: S-length scan every time. Keyed on the sampler config (logpost_fn
#: IDENTITY included — a new target is a new program); LRU-bounded so
#: sweeping many configs cannot leak executables.
_BLOCK_MEMO: OrderedDict = OrderedDict()
_BLOCK_MEMO_MAX = 32


def _memo(key, build):
    got = _BLOCK_MEMO.get(key)
    if got is None:
        got = build()
        _BLOCK_MEMO[key] = got
        while len(_BLOCK_MEMO) > _BLOCK_MEMO_MAX:
            _BLOCK_MEMO.popitem(last=False)
    else:
        _BLOCK_MEMO.move_to_end(key)
    return got


def _f():
    """Carry dtype: float32 by default, float64 under jax_enable_x64."""
    return jnp.result_type(float)


# ---------------------------------------------------------------------------
# Traceable target builders
# ---------------------------------------------------------------------------


def gaussian_target(mean, cov=None) -> Callable:
    """Traceable ``[K, d] -> [K]`` log-density of N(mean, cov) (cov=None: I).
    The analytic target used by the exactness tests and the dispatch-cost
    benchmark — evaluation is a handful of FLOPs, so steps/s measures the
    sampler loop itself."""
    mean = jnp.asarray(mean, _f())
    prec = None if cov is None else jnp.asarray(np.linalg.inv(np.atleast_2d(cov)), _f())

    def logpost(xs: jax.Array) -> jax.Array:
        r = xs - mean
        if prec is None:
            return -0.5 * jnp.sum(r * r, axis=-1)
        return -0.5 * jnp.einsum("ki,ij,kj->k", r, prec, r)

    return logpost


def gaussian_likelihood_target(
    forward_fn: Callable, data, noise_sd, prior_bounds=None
) -> Callable:
    """Traceable log-posterior from a native JAX batch forward model:
    Gaussian likelihood on the observables plus an optional uniform box
    prior (out-of-box rows get -inf BEFORE the accept step, mirroring the
    host `batched_logpost` prior mask). `forward_fn` must be a lockstep
    ``[K, d] -> [K, m]`` program (e.g. `apps.tsunami._solve_batch` under
    `functools.partial`) — per-row independence is also what lets the MALA
    block take per-chain gradients with one vjp (block-diagonal Jacobian).
    """
    data = jnp.asarray(np.asarray(data, float), _f())
    noise_sd = jnp.asarray(np.asarray(noise_sd, float), _f())
    if prior_bounds is not None:
        lo = jnp.asarray([b[0] for b in prior_bounds], _f())
        hi = jnp.asarray([b[1] for b in prior_bounds], _f())

    def logpost(xs: jax.Array) -> jax.Array:
        ys = jnp.asarray(forward_fn(xs), _f())
        ll = -0.5 * jnp.sum(((ys - data) / noise_sd) ** 2, axis=-1)
        if prior_bounds is None:
            return ll
        inbox = jnp.all((xs >= lo) & (xs <= hi), axis=-1)
        return jnp.where(inbox, ll, -jnp.inf)

    return logpost


def _value_and_grad_rows(logpost_fn: Callable):
    """(lps [K], dlps/dx [K, d]) in one vjp: the log-posterior rows depend
    only on their own chain's row (lockstep batch => block-diagonal
    Jacobian), so pulling back a vector of ones IS the per-row gradient."""

    def value_grad(xs: jax.Array) -> tuple[jax.Array, jax.Array]:
        lps, pull = jax.vjp(logpost_fn, xs)
        return lps, pull(jnp.ones_like(lps))[0]

    return value_grad


# ---------------------------------------------------------------------------
# Step kernels (scan bodies)
# ---------------------------------------------------------------------------


def _rwm_step(logpost_fn, L, active=None):
    L = jnp.asarray(L, _f())

    def step(carry, _):
        key, k_prop, k_u = jax.random.split(carry["key"], 3)
        xs, lps = carry["xs"], carry["lps"]
        props = xs + jax.random.normal(k_prop, xs.shape, xs.dtype) @ L.T
        lp_props = logpost_fn(props)
        log_alpha = lp_props - lps
        log_alpha = jnp.where(jnp.isnan(log_alpha), -jnp.inf, log_alpha)
        log_u = jnp.log(jax.random.uniform(k_u, lps.shape, lps.dtype))
        accept = log_u < log_alpha
        if active is not None:
            accept = accept & active
        xs = jnp.where(accept[:, None], props, xs)
        lps = jnp.where(accept, lp_props, lps)
        out = {"key": key, "xs": xs, "lps": lps,
               "acc": carry["acc"] + accept.astype(lps.dtype)}
        return out, (xs, lps)

    return step


def _pcn_step(loglik_fn, prior_chol, beta, active):
    L0 = jnp.asarray(prior_chol, _f())
    beta = float(beta)
    root = np.sqrt(1.0 - beta**2)

    def step(carry, _):
        key, k_prop, k_u = jax.random.split(carry["key"], 3)
        xs, lls = carry["xs"], carry["lps"]
        xi = jax.random.normal(k_prop, xs.shape, xs.dtype) @ L0.T
        props = root * xs + beta * xi
        ll_props = loglik_fn(props)
        log_alpha = ll_props - lls
        log_alpha = jnp.where(jnp.isnan(log_alpha), -jnp.inf, log_alpha)
        log_u = jnp.log(jax.random.uniform(k_u, lls.shape, lls.dtype))
        accept = (log_u < log_alpha) & active
        xs = jnp.where(accept[:, None], props, xs)
        lls = jnp.where(accept, ll_props, lls)
        out = {"key": key, "xs": xs, "lps": lls,
               "acc": carry["acc"] + accept.astype(lls.dtype)}
        return out, (xs, lls)

    return step


def _mala_step(logpost_fn, C, L, Cinv, active, adapt_steps, target_accept):
    value_grad = _value_and_grad_rows(logpost_fn)
    C, L, Cinv = (jnp.asarray(a, _f()) for a in (C, L, Cinv))
    n_active = None  # bound below (active is a concrete bool array)
    n_active = jnp.sum(active.astype(_f()))

    def _logq(diff_minus_drift, eps):
        return -0.5 / eps**2 * jnp.einsum(
            "ki,ij,kj->k", diff_minus_drift, Cinv, diff_minus_drift
        )

    def step(carry, _):
        key, k_prop, k_u = jax.random.split(carry["key"], 3)
        xs, lps, gs, eps, i = (carry[k] for k in ("xs", "lps", "gs", "eps", "i"))
        drift = 0.5 * eps**2 * gs @ C.T
        props = xs + drift + eps * jax.random.normal(k_prop, xs.shape, xs.dtype) @ L.T
        lp_props, g_props = value_grad(props)
        drift_rev = 0.5 * eps**2 * g_props @ C.T
        log_q_fwd = _logq(props - xs - drift, eps)
        log_q_rev = _logq(xs - props - drift_rev, eps)
        log_alpha = (lp_props - lps) + (log_q_rev - log_q_fwd)
        log_alpha = jnp.where(jnp.isnan(log_alpha), -jnp.inf, log_alpha)
        log_u = jnp.log(jax.random.uniform(k_u, lps.shape, lps.dtype))
        accept = (log_u < log_alpha) & active
        xs = jnp.where(accept[:, None], props, xs)
        lps = jnp.where(accept, lp_props, lps)
        gs = jnp.where(accept[:, None], g_props, gs)
        # Robbins-Monro on eps, acceptance pooled over ACTIVE lanes only
        # (pow2-padding lanes always reject and would bias the rate down)
        pooled = jnp.sum(accept.astype(lps.dtype)) / n_active
        eps = jnp.where(
            i < adapt_steps,
            eps * jnp.exp((i + 1.0) ** -0.6 * (pooled - target_accept)),
            eps,
        )
        out = {"key": key, "xs": xs, "lps": lps, "gs": gs,
               "acc": carry["acc"] + accept.astype(lps.dtype),
               "eps": eps, "i": i + 1}
        return out, (xs, lps)

    return step


# ---------------------------------------------------------------------------
# Block driver
# ---------------------------------------------------------------------------


def _run_fused(
    step_fn,
    carry: dict,
    *,
    n_steps: int,
    fused_steps: int,
    per_step: bool = False,
    ctx=None,
    telemetry=None,
    checkpoint=None,
    checkpoint_every: int = 0,
    scalar_keys: tuple = (),
):
    """Drive `n_steps` of `step_fn` in jitted blocks of `fused_steps`.

    Returns (samples [Kp, n, d], lps_out [Kp, n], final carry, n_blocks) as
    host numpy. ``per_step=True`` compiles the SAME scan program with S=1
    and dispatches it once per step with a host round trip in between — the
    per-step reference both the benchmark and the S=1 bit-exactness test
    compare against. Checkpoints land at block boundaries (effective
    interval: `checkpoint_every` rounded down to a block multiple) with the
    rng key-data manifest, so resume replays the identical key stream."""
    S = 1 if per_step else int(fused_steps)
    if S < 1:
        raise ValueError(f"fused_steps must be >= 1, got {S}")
    if n_steps % S:
        raise ValueError(f"n_steps={n_steps} not a multiple of fused_steps={S}")
    n_blocks = n_steps // S
    Kp, d = carry["xs"].shape

    def _build_block():
        def block(c):
            return jax.lax.scan(step_fn, c, None, length=S)

        if ctx is not None:
            from repro.distributed.sharding import chain_carry_shardings

            csh = chain_carry_shardings(ctx, carry, Kp)
            ysh = ctx.sharding(None, "batch")  # scan stacks [S, Kp, ...]
            return jax.jit(block, in_shardings=(csh,),
                           out_shardings=(csh, (ysh, ysh)))
        return jax.jit(block)

    # memoized on the (already memoized) step closure: a repeat call with
    # the same sampler config reuses the compiled S-length scan
    block_jit = _memo(("block", step_fn, S, Kp, ctx), _build_block)

    samples = np.empty((Kp, n_steps, d))
    lps_out = np.empty((Kp, n_steps))
    start_block = 0
    resumed = checkpoint.resume() if checkpoint is not None else None
    if resumed is not None:
        arrays, meta, _step = resumed
        done = int(meta["steps_done"])
        start_block = done // S
        for k, v in carry.items():
            if k == "key":
                carry[k] = _unpack_key(arrays["rng_key"])
            else:
                carry[k] = jnp.asarray(arrays[k], v.dtype)
        samples[:, :done] = arrays["samples"]
        lps_out[:, :done] = arrays["lps_out"]

    def dispatch(c):
        if ctx is not None:
            with ctx.mesh:
                return block_jit(c)
        return block_jit(c)

    every_blocks = max(1, checkpoint_every // S) if checkpoint_every else 0
    for b in range(start_block, n_blocks):
        carry, (xs_blk, lps_blk) = dispatch(carry)
        lo = b * S
        # host pull — ONE round trip per block (per step when per_step=True)
        samples[:, lo:lo + S] = np.moveaxis(np.asarray(xs_blk), 0, 1)
        lps_out[:, lo:lo + S] = np.asarray(lps_blk).T
        if telemetry is not None:
            telemetry.note_steps(S, waves=1)
            # service-tier campaigns meter device-resident work through this
            # optional hook (budget charge + per-tenant fused-step telemetry;
            # it does NOT re-count steps — note_steps above already did)
            nb = getattr(telemetry, "note_fused_block", None)
            if nb is not None:
                nb(len(samples), S)
        if checkpoint is not None and every_blocks and (b + 1) % every_blocks == 0:
            done = (b + 1) * S
            arrays = {k: np.asarray(v) for k, v in carry.items() if k != "key"}
            arrays["rng_key"] = _pack_key(carry["key"])
            arrays["samples"] = samples[:, :done].copy()
            arrays["lps_out"] = lps_out[:, :done].copy()
            checkpoint.save(done, arrays, {
                "steps_done": done, "fused_steps": S,
                **{k: float(np.asarray(carry[k])) for k in scalar_keys},
            })
    return samples, lps_out, carry, n_blocks


def _pack_key(key) -> np.ndarray:
    from repro.core.fleet import CampaignCheckpoint

    return CampaignCheckpoint.pack_key(key)


def _unpack_key(data) -> jax.Array:
    from repro.core.fleet import CampaignCheckpoint

    return CampaignCheckpoint.unpack_key(data)


def _pad_chains(x0s: np.ndarray, ctx) -> tuple[np.ndarray, int]:
    """(padded x0s, original K): pow2 bucketing so every mesh/tile shape is
    one of a handful of specializations — identical to the evaluate path."""
    K = len(x0s)
    if ctx is None:
        return x0s, K
    bucket = max(next_pow2(K), ctx.n_data)
    padded, _ = pad_to_bucket(x0s, bucket)
    return padded, K


def _init_carry(x0s, key, ctx):
    dt = _f()
    x0s = np.atleast_2d(np.asarray(x0s, float))
    padded, K = _pad_chains(x0s, ctx)
    Kp = len(padded)
    xs = jnp.asarray(padded, dt)
    active = jnp.arange(Kp) < K
    return xs, active, K, Kp, key


# ---------------------------------------------------------------------------
# Fused runners (EnsembleResult-compatible)
# ---------------------------------------------------------------------------


def fused_ensemble_rwm(
    logpost_fn: Callable,
    x0s: np.ndarray,
    n_steps: int,
    prop_cov: np.ndarray,
    key,
    *,
    fused_steps: int,
    per_step: bool = False,
    ctx=None,
    telemetry=None,
    checkpoint=None,
    checkpoint_every: int = 0,
) -> EnsembleResult:
    """K lockstep RWM chains, S steps per device dispatch."""
    xs, active, K, Kp, key = _init_carry(x0s, key, ctx)
    L = np.linalg.cholesky(np.atleast_2d(prop_cov))
    step = _memo(("rwm", logpost_fn, L.tobytes(), K, Kp),
                 lambda: _rwm_step(logpost_fn, L, active))
    lps0 = jax.jit(logpost_fn)(xs)
    carry = {"key": key, "xs": xs, "lps": lps0,
             "acc": jnp.zeros(Kp, _f())}
    samples, lps_out, carry, n_blocks = _run_fused(
        step, carry, n_steps=n_steps, fused_steps=fused_steps,
        per_step=per_step, ctx=ctx, telemetry=telemetry,
        checkpoint=checkpoint, checkpoint_every=checkpoint_every,
    )
    acc = np.asarray(carry["acc"])[:K]
    return EnsembleResult(
        samples[:K], lps_out[:K], acc / n_steps,
        K * (n_steps + 1), n_blocks + 1,
    )


def fused_ensemble_pcn(
    loglik_fn: Callable,
    x0s: np.ndarray,
    n_steps: int,
    beta: float,
    key,
    *,
    prior_chol: np.ndarray | None = None,
    fused_steps: int,
    per_step: bool = False,
    ctx=None,
    telemetry=None,
    checkpoint=None,
    checkpoint_every: int = 0,
) -> EnsembleResult:
    """K lockstep pCN chains (centered Gaussian prior with Cholesky factor
    `prior_chol`, default I), S steps per device dispatch."""
    xs, active, K, Kp, key = _init_carry(x0s, key, ctx)
    d = xs.shape[1]
    L0 = np.eye(d) if prior_chol is None else np.atleast_2d(prior_chol)
    step = _memo(("pcn", loglik_fn, L0.tobytes(), float(beta), K, Kp),
                 lambda: _pcn_step(loglik_fn, L0, beta, active))
    lls0 = jax.jit(loglik_fn)(xs)
    carry = {"key": key, "xs": xs, "lps": lls0,
             "acc": jnp.zeros(Kp, _f())}
    samples, lps_out, carry, n_blocks = _run_fused(
        step, carry, n_steps=n_steps, fused_steps=fused_steps,
        per_step=per_step, ctx=ctx, telemetry=telemetry,
        checkpoint=checkpoint, checkpoint_every=checkpoint_every,
    )
    acc = np.asarray(carry["acc"])[:K]
    return EnsembleResult(
        samples[:K], lps_out[:K], acc / n_steps,
        K * (n_steps + 1), n_blocks + 1,
    )


def fused_ensemble_mala(
    logpost_fn: Callable,
    x0s: np.ndarray,
    n_steps: int,
    step_size: float,
    key,
    *,
    precond: np.ndarray | None = None,
    adapt_steps: int = 0,
    target_accept: float = 0.574,
    fused_steps: int,
    per_step: bool = False,
    ctx=None,
    telemetry=None,
    checkpoint=None,
    checkpoint_every: int = 0,
) -> EnsembleResult:
    """K lockstep MALA chains, S steps per device dispatch: drift gradients
    come from ONE vjp of the traceable log-posterior per step (block-
    diagonal Jacobian, see `_value_and_grad_rows`), and Robbins-Monro
    step-size adaptation runs inside the scan on the active-lane-pooled
    acceptance rate."""
    xs, active, K, Kp, key = _init_carry(x0s, key, ctx)
    d = xs.shape[1]
    C = np.eye(d) if precond is None else np.atleast_2d(np.asarray(precond, float))
    L = np.linalg.cholesky(C)
    Cinv = np.linalg.inv(C)
    step = _memo(
        ("mala", logpost_fn, C.tobytes(), int(adapt_steps),
         float(target_accept), K, Kp),
        lambda: _mala_step(logpost_fn, C, L, Cinv, active,
                           int(adapt_steps), float(target_accept)))
    lps0, gs0 = _memo(("mala-init", logpost_fn),
                      lambda: jax.jit(_value_and_grad_rows(logpost_fn)))(xs)
    carry = {
        "key": key, "xs": xs, "lps": lps0, "gs": gs0,
        "acc": jnp.zeros(Kp, _f()),
        "eps": jnp.asarray(float(step_size), _f()),
        "i": jnp.asarray(0, jnp.int32),
    }
    samples, lps_out, carry, n_blocks = _run_fused(
        step, carry, n_steps=n_steps, fused_steps=fused_steps,
        per_step=per_step, ctx=ctx, telemetry=telemetry,
        checkpoint=checkpoint, checkpoint_every=checkpoint_every,
        scalar_keys=("eps",),
    )
    acc = np.asarray(carry["acc"])[:K]
    return EnsembleResult(
        samples[:K], lps_out[:K], acc / n_steps,
        K * (n_steps + 1), n_blocks + 1,
        n_grad_waves=n_blocks + 1,
        final_step_size=float(np.asarray(carry["eps"])),
    )


def make_fused_rwm_subchain(
    logpost_fn: Callable, n_sub: int, prop_chol: np.ndarray
) -> Callable:
    """Compile-once fused RWM subchain for MLDA coarse levels.

    Returns ``run(xs, key) -> (ys, lp_ys, lp_start, acc_counts, key)``:
    all K chains advance `n_sub` coarse steps in ONE device dispatch (plus
    one for the start log-densities) and come back with exactly the
    quantities the delayed-acceptance ratio needs. No sample collection, no
    host traffic inside the subchain, and BOTH lp_start and lp_ys come from
    the same traceable `logpost_fn`, so the DA correction stays exact. The
    block program is jitted once here, not per subchain call."""
    step = _rwm_step(logpost_fn, prop_chol)
    init_lp = jax.jit(logpost_fn)

    @jax.jit
    def block(key, xs, lps):
        carry = {"key": key, "xs": xs, "lps": lps,
                 "acc": jnp.zeros(xs.shape[0], xs.dtype)}
        out, _ = jax.lax.scan(step, carry, None, length=n_sub)
        return out

    def run(xs, key):
        xs = jnp.asarray(np.atleast_2d(np.asarray(xs, float)), _f())
        lps = init_lp(xs)
        out = block(key, xs, lps)
        return (
            np.asarray(out["xs"], float), np.asarray(out["lps"], float),
            np.asarray(lps, float), np.asarray(out["acc"]), out["key"],
        )

    return run
