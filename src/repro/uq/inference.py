"""Second-order posterior previews over batched waves (ROADMAP item 5).

Gaussian-likelihood inverse problems (y_obs ~ N(F(theta), Gamma), Gaussian
prior N(mu0, Sigma0)) get two fast "preview" estimators of the posterior
long before an MCMC campaign is affordable:

* `laplace_preview` — ensemble Gauss-Newton/Newton MAP search with a
  Laplace (Gaussian) approximation at the optimum. K candidates advance in
  LOCKSTEP; each iterate costs one fused value-and-gradient wave (misfits +
  gradients for the whole ensemble) plus one batched curvature-probe wave
  set: a `[K*d]`-lane JVP wave assembling the Jacobians and — with
  `curvature="full"` — a `[K*d]`-lane Hessian-apply wave riding the new
  `/ApplyHessianBatch` route for the exact second-order correction. No
  per-point model calls anywhere.

* `ensemble_kalman_inversion` (EKI) — derivative-free fallback for
  evaluate-only backends: a tempered ensemble Kalman update with perturbed
  observations, one `evaluate_batch` wave per tempering step. Exact in the
  linear-Gaussian large-ensemble limit; a controlled preview otherwise.

`posterior_preview` negotiates between them on the evaluator's capability
surface: it tries the second-order path and degrades to EKI when the
fabric/model raises `UnsupportedCapability` (e.g. an evaluate-only HTTP
cluster).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.interface import UnsupportedCapability


@dataclass
class LaplaceResult:
    """MAP point + Laplace (Gaussian) posterior approximation."""

    mean: np.ndarray  # [d] MAP estimate
    cov: np.ndarray  # [d, d] inverse curvature at the MAP
    neg_logpost: float  # U(mean) = misfit + prior potential (up to consts)
    thetas: np.ndarray  # [K, d] final ensemble (all local optima found)
    neg_logposts: np.ndarray  # [K]
    n_iters: int
    waves: int
    converged: bool
    method: str = "laplace"
    history: list = field(default_factory=list)  # per-iterate min U


@dataclass
class EKIResult:
    """Tempered ensemble Kalman inversion posterior preview."""

    mean: np.ndarray  # [d] ensemble mean
    cov: np.ndarray  # [d, d] ensemble covariance
    thetas: np.ndarray  # [J, d] final ensemble
    n_iters: int
    waves: int
    misfit_history: list = field(default_factory=list)
    method: str = "eki"


def _spd_cov(cov, d: int) -> np.ndarray:
    """Accept a scalar variance, a [d] diagonal or a full [d, d] matrix."""
    cov = np.asarray(cov, float)
    if cov.ndim == 0:
        return np.eye(d) * float(cov)
    if cov.ndim == 1:
        return np.diag(cov)
    return np.atleast_2d(cov)


def _chol_solve(H: np.ndarray, b: np.ndarray) -> np.ndarray | None:
    """H^{-1} b via Cholesky; None when H is not positive definite."""
    try:
        L = np.linalg.cholesky(H)
    except np.linalg.LinAlgError:
        return None
    z = np.linalg.solve(L, b)
    return np.linalg.solve(L.T, z)


def laplace_preview(
    evaluator,
    y_obs,
    noise_cov,
    prior_mean,
    prior_cov,
    *,
    n_ensemble: int = 4,
    n_iters: int = 12,
    curvature: str = "full",
    grad_tol: float = 1e-6,
    damping: float = 1e-6,
    rng: np.random.Generator | None = None,
    config: dict | None = None,
) -> LaplaceResult:
    """Ensemble Newton MAP search + Laplace approximation, in batched waves.

    Minimizes U(theta) = 0.5 ||Gamma^{-1/2} (F(theta) - y_obs)||^2
    + 0.5 (theta - mu0)^T Sigma0^{-1} (theta - mu0) from `n_ensemble`
    lockstep starts (the prior mean plus prior draws). Per iterate:

    * ONE fused value-and-gradient wave over the `[K, d]` ensemble block
      (`sens_fn = Gamma^{-1}(y_obs - y)`, so AD backends fuse the primal
      and the VJP into a single dispatch);
    * ONE `[K*d]`-lane JVP wave probing the Jacobians column by column
      (J_k e_j for every member and every basis vector), giving the exact
      Gauss-Newton curvature J^T Gamma^{-1} J;
    * with `curvature="full"`, ONE `[K*d]`-lane Hessian-apply wave
      (`apply_hessian_batch` with sens = Gamma^{-1}(F - y_obs)) adding the
      exact second-order term sum_i s_i grad^2 F_i — the batched HVP rides
      `/ApplyHessianBatch` end to end on HTTP backends.

    The Newton system uses the prior precision as exact regularization, so
    on a LINEAR model the first undamped step lands on the exact posterior
    mean and `cov` equals the exact posterior covariance. When the full
    Hessian is indefinite the member falls back to its Gauss-Newton matrix
    (plus `damping` I as a last resort) — curvature corrections can only
    sharpen the preview, never break descent. Per-member backtracking
    reuses the NEXT iterate's value wave, so rejected steps cost no extra
    dispatches.
    """
    if curvature not in ("full", "gn"):
        raise ValueError(f"curvature must be 'full' or 'gn', got {curvature!r}")
    rng = np.random.default_rng(0) if rng is None else rng
    mu0 = np.asarray(prior_mean, float).ravel()
    d = mu0.size
    Sigma0 = _spd_cov(prior_cov, d)
    P0 = np.linalg.inv(Sigma0)  # prior precision
    y_obs = np.asarray(y_obs, float).ravel()
    m = y_obs.size
    Gamma = _spd_cov(noise_cov, m)
    Ginv = np.linalg.inv(Gamma)

    K = max(1, int(n_ensemble))
    thetas = np.vstack([mu0, rng.multivariate_normal(mu0, Sigma0, size=K - 1)]) \
        if K > 1 else mu0[None, :]
    waves = 0

    def sens_fn(y):
        # dloglik/dy at one output row (np constants trace fine under jax)
        return Ginv @ (y_obs - y)

    def value_grad(block):
        """(U [K], grad_U [K, d], residuals [K, m]) in one fused wave."""
        ys, glik = evaluator.value_and_gradient_batch(block, sens_fn, config)
        ys = np.atleast_2d(np.asarray(ys, float))
        r = ys - y_obs  # [K, m]
        dtheta = block - mu0
        U = 0.5 * np.einsum("ki,ij,kj->k", r, Ginv, r) \
            + 0.5 * np.einsum("ki,ij,kj->k", dtheta, P0, dtheta)
        grad = -np.atleast_2d(np.asarray(glik, float)) + dtheta @ P0.T
        return U, grad, r

    def curvatures(block, residuals):
        """Exact per-member Hessians of U via batched probe waves: the
        ensemble x basis-vector grid flattens into single [K*d]-lane
        dispatches (never K*d round-trips)."""
        Kb = len(block)
        rep = np.repeat(block, d, axis=0)  # [K*d, d]
        probes = np.tile(np.eye(d), (Kb, 1))  # [K*d, d]
        jcols = np.atleast_2d(np.asarray(
            evaluator.apply_jacobian_batch(rep, probes, config), float
        )).reshape(Kb, d, m)  # [K, d(cols), m]
        H = np.einsum("kim,mn,kjn->kij", jcols, Ginv, jcols)  # J^T Ginv J
        M = None
        if curvature == "full":
            senss = np.repeat(residuals @ Ginv.T, d, axis=0)  # Ginv (F - y)
            M = np.atleast_2d(np.asarray(
                evaluator.apply_hessian_batch(rep, senss, probes, config), float
            )).reshape(Kb, d, d)
            M = 0.5 * (M + np.transpose(M, (0, 2, 1)))
        return H, M

    U, grad, resid = value_grad(thetas)
    waves += 1
    alphas = np.ones(K)
    history = [float(np.nanmin(U))]
    H_members = np.tile(P0, (K, 1, 1))
    it = 0
    for it in range(1, n_iters + 1):
        Hgn, M = curvatures(thetas, resid)
        waves += 2 if M is not None else 1
        steps = np.zeros_like(thetas)
        for k in range(K):  # host-side linear algebra only, no model calls
            Hk = Hgn[k] + P0
            p = None
            if M is not None:
                p = _chol_solve(Hk + M[k], grad[k])
                if p is not None:
                    Hk = Hk + M[k]
            if p is None:
                p = _chol_solve(Hk, grad[k])
            if p is None:
                Hk = Hk + damping * np.eye(d)
                p = _chol_solve(Hk, grad[k])
            steps[k] = -p if p is not None else -grad[k]
            H_members[k] = Hk
        gnorm = np.linalg.norm(grad, axis=1)
        if np.all(gnorm < grad_tol):
            break
        props = thetas + alphas[:, None] * steps
        U_new, grad_new, resid_new = value_grad(props)
        waves += 1
        better = np.isfinite(U_new) & (U_new <= U + 1e-12)
        # per-member backtracking against the wave just paid: rejected
        # members revert and halve their step for the next iterate
        alphas = np.where(better, np.minimum(1.0, alphas * 2.0), alphas * 0.5)
        thetas = np.where(better[:, None], props, thetas)
        grad = np.where(better[:, None], grad_new, grad)
        resid = np.where(better[:, None], resid_new, resid)
        U = np.where(better, U_new, U)
        history.append(float(np.nanmin(U)))
    best = int(np.nanargmin(U))
    # Laplace covariance at the winner, from its LAST assembled curvature
    Hgn, M = curvatures(thetas[best][None, :], resid[best][None, :])
    waves += 2 if M is not None else 1
    Hbest = Hgn[0] + P0 + (M[0] if M is not None else 0.0)
    cov = _chol_solve(Hbest, np.eye(d))
    if cov is None:  # indefinite full Hessian at a shoulder: GN fallback
        cov = _chol_solve(Hgn[0] + P0, np.eye(d))
    return LaplaceResult(
        mean=thetas[best].copy(),
        cov=np.asarray(cov),
        neg_logpost=float(U[best]),
        thetas=thetas,
        neg_logposts=U,
        n_iters=it,
        waves=waves,
        converged=bool(np.all(np.linalg.norm(grad, axis=1) < max(grad_tol, 1e-4))),
        history=history,
    )


def ensemble_kalman_inversion(
    evaluator,
    y_obs,
    noise_cov,
    prior_mean,
    prior_cov,
    *,
    n_ensemble: int = 256,
    n_iters: int = 1,
    rng: np.random.Generator | None = None,
    config: dict | None = None,
) -> EKIResult:
    """Tempered EKI with perturbed observations: one `evaluate_batch` wave
    per tempering step, NO derivatives — the preview for evaluate-only
    backends. Uniform tempering (each of the `n_iters` steps uses inflated
    noise Gamma/alpha with alpha = 1/n_iters, summing to one full Bayes
    update), so `n_iters=1` is the classic single Kalman update: exact
    posterior moments for linear-Gaussian problems as the ensemble grows.
    """
    rng = np.random.default_rng(0) if rng is None else rng
    mu0 = np.asarray(prior_mean, float).ravel()
    d = mu0.size
    Sigma0 = _spd_cov(prior_cov, d)
    y_obs = np.asarray(y_obs, float).ravel()
    m = y_obs.size
    Gamma = _spd_cov(noise_cov, m)

    J = max(int(n_ensemble), d + 2)
    thetas = rng.multivariate_normal(mu0, Sigma0, size=J)
    waves = 0
    alpha = 1.0 / max(1, int(n_iters))
    misfits = []
    for _ in range(max(1, int(n_iters))):
        ys = np.atleast_2d(np.asarray(
            evaluator.evaluate_batch(thetas, config), float
        ))
        waves += 1
        misfits.append(float(np.mean(
            np.einsum("ki,ij,kj->k", ys - y_obs, np.linalg.inv(Gamma), ys - y_obs)
        )) * 0.5)
        t_c = thetas - thetas.mean(0)
        y_c = ys - ys.mean(0)
        C_ty = t_c.T @ y_c / (J - 1)  # [d, m]
        C_yy = y_c.T @ y_c / (J - 1)  # [m, m]
        gain = C_ty @ np.linalg.inv(C_yy + Gamma / alpha)
        noise = rng.multivariate_normal(np.zeros(m), Gamma / alpha, size=J)
        thetas = thetas + (y_obs + noise - ys) @ gain.T
    return EKIResult(
        mean=thetas.mean(0),
        cov=np.cov(thetas.T).reshape(d, d),
        thetas=thetas,
        n_iters=max(1, int(n_iters)),
        waves=waves,
        misfit_history=misfits,
    )


def posterior_preview(
    evaluator,
    y_obs,
    noise_cov,
    prior_mean,
    prior_cov,
    *,
    rng: np.random.Generator | None = None,
    config: dict | None = None,
    **kwargs,
) -> LaplaceResult | EKIResult:
    """Capability-negotiated preview: second-order Laplace when the
    evaluator serves derivative waves, tempered EKI when it is
    evaluate-only (`UnsupportedCapability` from any derivative dispatch
    downgrades — mirrors the client/fabric negotiation ladder). The result
    carries `method` ("laplace" or "eki")."""
    lap_keys = ("n_ensemble", "n_iters", "curvature", "grad_tol", "damping")
    try:
        return laplace_preview(
            evaluator, y_obs, noise_cov, prior_mean, prior_cov,
            rng=rng, config=config,
            **{k: v for k, v in kwargs.items() if k in lap_keys},
        )
    except (UnsupportedCapability, AttributeError, TypeError):
        pass
    eki_keys = ("n_iters",)
    eki_kwargs = {k: v for k, v in kwargs.items() if k in eki_keys}
    eki_kwargs.setdefault("n_ensemble", kwargs.get("eki_ensemble", 256))
    return ensemble_kalman_inversion(
        evaluator, y_obs, noise_cov, prior_mean, prior_cov,
        rng=rng, config=config, **eki_kwargs,
    )
