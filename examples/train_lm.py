"""Train a small LM end-to-end with the production train loop (checkpointing,
fault policy, deterministic data) — a scaled-down qwen3 on CPU.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

import jax

from repro.configs import get_config
from repro.distributed.sharding import ShardingCtx, make_test_mesh
from repro.launch.train import train
from repro.types import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    ctx = ShardingCtx(make_test_mesh(1, 1))
    tc = TrainConfig(
        lr=1e-3, warmup_steps=args.steps // 10, total_steps=args.steps,
        checkpoint_every=50,
    )
    _, _, hist = train(
        cfg, ctx, tc, steps=args.steps, global_batch=8, seq_len=128,
        ckpt_dir="checkpoints/example", log_every=20,
    )
    print(f"\nNLL {hist[0][1]:.3f} -> {hist[-1][1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
