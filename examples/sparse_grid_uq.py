"""Paper §4.1: sparse-grid UQ of ship resistance with the L2-Sea analogue —
the SGMK Matlab snippet, line for line, in this framework.

Run: PYTHONPATH=src python examples/sparse_grid_uq.py
"""
import numpy as np

from repro.apps.l2sea import DRAFT_RANGE, FROUDE_RANGE, L2SeaModel, make_inputs
from repro.core.fabric import EvaluationFabric
from repro.core.pool import ThreadedPool
from repro.uq import sparse_grid as sg
from repro.uq.distributions import Beta, Triangular
from repro.uq.kde import kde


def main():
    # fabric = EvaluationFabric(['http://104.199.68.148'])  # the real server
    # (here: in-process pool of 8 instances — the UQ code is identical;
    # swapping the backend is the paper's separation-of-concerns claim)
    fabric = EvaluationFabric(ThreadedPool([L2SeaModel() for _ in range(8)]))
    config = {"fidelity": 3, "sinkoff": "y", "trimoff": "y"}

    # L2-Sea takes 16 inputs but we use only the first two
    f = lambda y: fabric.evaluate_batch(make_inputs(y), config)

    # knots for F (triangular) and D (beta), nested Leja families
    knots_froude = sg.knots_triangular_leja(*FROUDE_RANGE)
    knots_draft = sg.knots_beta_leja(10, 10, *DRAFT_RANGE)

    # build sparse grid  (N=2; w=5)
    S = sg.smolyak_grid(2, 5, [knots_froude, knots_draft])
    Sr = sg.reduce_sparse_grid(S)
    print(f"sparse grid: {len(Sr.points)} points")

    # call L2-Sea on each point (the pool parallelizes — Matlab's parfor)
    f_values = sg.evaluate_on_sparse_grid(f, Sr)

    # random sample of (F, D) by their PDFs, evaluate the surrogate
    rng = np.random.default_rng(0)
    froude, draft = Triangular(*FROUDE_RANGE), Beta(10, 10, *DRAFT_RANGE)
    random_sample = np.stack([froude.sample(rng, 5000), draft.sample(rng, 5000)], 1)
    surrogate_evals = sg.interpolate_on_sparse_grid(S, Sr, f_values, random_sample)

    # ksdensity(..., 'support','positive','Bandwidth',0.1)
    ksd_pdf, ksd_points = kde(surrogate_evals[:, 0], support="positive", bandwidth=0.1)
    mode = ksd_points[np.argmax(ksd_pdf)]
    print(f"PDF of R_T: mode ~ {mode:.1f} kN, mean ~ {surrogate_evals.mean():.1f} kN")
    fabric.shutdown()


if __name__ == "__main__":
    main()
