"""Quickstart — the paper's §2.4 minimal client/server example, in this
framework.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.client import HTTPModel, supported_models
from repro.core.fabric import EvaluationFabric
from repro.core.interface import JAXModel, Model
from repro.core.pool import ModelPool
from repro.core.server import serve_models


# --- a model server (paper §2.4.2: multiply the single input by two) -------
class TestModel(Model):
    def __init__(self):
        super().__init__("forward")

    def get_input_sizes(self, config=None):
        return [1]

    def get_output_sizes(self, config=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, parameters, config=None):
        return [[parameters[0][0] * 2]]


def main():
    # 1) serve it over the UM-Bridge HTTP protocol (paper §2.4.2)
    server, _ = serve_models([TestModel()], 4242, background=True)

    # 2) call it like the paper's §2.4.1 client
    url = "http://localhost:4242"
    print("models:", supported_models(url))
    model = HTTPModel(url, "forward")
    print("F([10]) =", model([[10.0]]))

    # 3) the JAX-native path: ONE pure function gives the whole UM-Bridge
    #    surface (evaluate/gradient/Jacobian/Hessian) via AD...
    jm = JAXModel(lambda th: jnp.array([th[0] ** 3 + th[1]]), 2, 1)
    print("F(2,1)    =", jm([[2.0, 1.0]]))
    print("grad      =", jm.gradient(0, 0, [[2.0, 1.0]], [1.0]))
    print("J [1,0]^T =", jm.apply_jacobian(0, 0, [[2.0, 1.0]], [1.0, 0.0]))
    print("H action  =", jm.apply_hessian(0, 0, 0, [[2.0, 1.0]], [1.0], [1.0, 0.0]))

    # 4) ...and scales out through the SPMD pool (the paper's k8s cluster)
    pool = ModelPool(jm)
    thetas = np.random.default_rng(0).standard_normal((10, 2))
    print("pool(10 points) ->", pool.evaluate(thetas).ravel().round(2))

    # 5) the EvaluationFabric is the one dispatch layer UQ drivers talk to:
    #    per-point submits batch into waves, duplicates hit the LRU cache,
    #    and the SAME API fans out over HTTP servers or thread pools
    with EvaluationFabric(pool) as fabric:
        futs = [fabric.submit(t) for t in thetas] + [fabric.submit(thetas[0])]
        print("fabric(11 submits) ->", np.round([f.result()[0] for f in futs], 2))
        t = fabric.telemetry()
        print(f"fabric telemetry: {t['waves']} waves, {t['points']} evals, "
              f"{t['cache_hits'] + t['coalesced']} deduped")

    server.shutdown()


if __name__ == "__main__":
    main()
