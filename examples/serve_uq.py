"""End-to-end serving driver (the paper's deployment kind): a small LM served
behind the UM-Bridge interface with batched parallel requests from a UQ
method — sparse-grid + MC sensitivity of the LM's NLL to (embedding scale,
temperature).

Run: PYTHONPATH=src python examples/serve_uq.py
"""
import numpy as np

from repro.apps.lm_model import LMUQModel
from repro.core.fabric import EvaluationFabric
from repro.core.pool import ModelPool
from repro.uq import sparse_grid as sg
from repro.uq.monte_carlo import monte_carlo


def main():
    # the "expensive model": an LM forward pass (reduced config on CPU; the
    # same wrapper drives a 104B model on the production mesh)
    lm = LMUQModel("qwen3-0.6b", reduced=True, batch=2, seq=64)
    pool = ModelPool(lm)
    fabric = EvaluationFabric(pool)  # ONE dispatch layer for every request kind
    print(f"serving {lm.name}: {pool.n_instances} instance(s)")

    # 1) batched requests through the fabric (the paper's cluster dispatch)
    with lm.ctx.mesh:
        # sparse-grid surrogate of NLL(emb_scale, temperature) — the driver
        # accepts the fabric directly in place of a bare callable
        knots = [sg.knots_uniform_leja(0.7, 1.3), sg.knots_uniform_leja(0.7, 1.3)]
        S = sg.smolyak_grid(2, 4, knots)
        Sr = sg.reduce_sparse_grid(S)
        vals = sg.evaluate_on_sparse_grid(fabric, Sr)
        print(f"sparse grid: {len(Sr.points)} LM evaluations")

        # surrogate-based forward UQ: emb_scale ~ U(0.9,1.1), temp ~ U(0.8,1.2)
        rng = np.random.default_rng(0)
        sample = np.stack([rng.uniform(0.9, 1.1, 4000), rng.uniform(0.8, 1.2, 4000)], 1)
        nlls = sg.interpolate_on_sparse_grid(S, Sr, vals, sample)[:, 0]
        print(f"NLL under calibration uncertainty: mean={nlls.mean():.4f} "
              f"std={nlls.std():.4f} p95={np.percentile(nlls, 95):.4f}")

        # 2) per-point submits (prototype-style code) batch transparently
        futs = [fabric.submit([1.0 + 0.02 * i, 1.0]) for i in range(8)]
        sens = [float(f.result()[0]) for f in futs]
        print("NLL vs embedding scale 1.00..1.14:", np.round(sens, 4))
        t = fabric.telemetry()
        print(f"fabric: {t['waves']} waves for {t['points']} evaluations "
              f"(mean wave {t['mean_wave_size']:.1f})")

        # 3) gradients through the SAME interface (AD, no extra model code)
        g = lm.gradient(0, 0, [[1.0, 1.0]], [1.0])
        print(f"dNLL/d(emb_scale, temp) = ({g[0]:.4f}, {g[1]:.4f})")
    fabric.shutdown()


if __name__ == "__main__":
    main()
