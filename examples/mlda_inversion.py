"""Paper §4.3: tsunami source inversion with 3-level MLDA
(GP emulator <- smoothed SWE <- fully-resolved SWE).

Two sampling disciplines over the same hierarchy:

* independent chains (`run_chains` + `mlda`) — the paper's 100-parallel-
  samplers pattern; the fabric coalesces their requests into waves;
* `ensemble_mlda` — K chains in LOCKSTEP: every coarse-subchain step and
  fine acceptance test across all chains is ONE `evaluate_batch` wave.

Run: PYTHONPATH=src:. python examples/mlda_inversion.py
"""
import numpy as np

from benchmarks.mlda_tsunami import PRIOR, TRUE_THETA, build_hierarchy
from repro.uq.mcmc import run_chains
from repro.uq.mlda import batched_level_logposts, ensemble_mlda, mlda


def main():
    # the PDE levels arrive already routed through ONE EvaluationFabric:
    # parallel chains coalesce into dispatch waves and repeated coarse
    # states are served from its result cache
    h = build_hierarchy(n_gp_train=64)
    logposts, data, fabric = h["logposts"], h["data"], h["fabric"]
    print("observed data (arrival_1, height_1, arrival_2, height_2):", np.round(data, 3))

    prop_cov = np.diag([8.0**2, 0.25**2])

    def chain(i):
        rng = np.random.default_rng(100 + i)
        x0 = np.array([rng.uniform(*PRIOR[0]), rng.uniform(*PRIOR[1])])
        return mlda(logposts, x0, 5, [10, 2], prop_cov, rng)

    results = run_chains(chain, n_chains=4)
    samples = np.concatenate([r.samples for r in results])
    evals = np.sum([r.evals_per_level for r in results], axis=0)
    t = fabric.telemetry()
    print(f"posterior mean: x0={samples[:,0].mean():.1f} km (true {TRUE_THETA[0]}), "
          f"A={samples[:,1].mean():.2f} m (true {TRUE_THETA[1]})")
    print(f"model evaluations per level (GP, smoothed, fine): {evals.tolist()}")
    print(f"fabric cache served {t['cache_hits']} of "
          f"{t['cache_hits'] + t['cache_misses']} PDE requests "
          f"({t['cache_hit_rate']:.0%})")
    print("the GP absorbs the sampling burden; the fine solver runs",
          f"only {evals[2]} times — the paper's multilevel economics")

    # --- ensemble MLDA quickstart: K lockstep chains, one wave per step ----
    rng = np.random.default_rng(7)
    x0s = np.stack(
        [rng.uniform(*PRIOR[0], 8), rng.uniform(*PRIOR[1], 8)], axis=1
    )
    lp_batches = [
        h["gp_logpost_batch"],
        *batched_level_logposts(fabric, h["loglik"],
                                [{"level": 0}, {"level": 1}], h["logprior"]),
    ]
    res = ensemble_mlda(
        lp_batches, x0s, n_samples=5, subsampling=[10, 2],
        prop_cov=prop_cov, rng=rng,
    )
    pooled = res.samples_flat
    print(f"ensemble MLDA: 8 lockstep chains x 5 fine samples in "
          f"{res.n_waves} waves (vs ~{int(np.sum(res.evals_per_level))} "
          f"per-point round-trips); pooled mean "
          f"x0={pooled[:, 0].mean():.1f} km, A={pooled[:, 1].mean():.2f} m")
    fabric.shutdown()


if __name__ == "__main__":
    main()
