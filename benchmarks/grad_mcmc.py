"""Gradient-based lockstep sampling on the coarse tsunami posterior.

The capability-typed model surface (PR: Evaluate/Gradient/ApplyJacobian
parity) is what makes this benchmark POSSIBLE: `ensemble_mala` drives one
fused value-and-gradient wave per step through the fabric — the tsunami
model computes the primal and the adjoint (sens^T J through ~2k SWE steps)
in ONE jitted dispatch for all K chains — where ensemble RWM drives one
evaluate wave per step. At matched wall time, MALA's drift-informed
proposals must buy >= 2x the effective samples PER WAVE of RWM's blind ones
(the acceptance bar), with the per-capability wave split visible in
`fabric.telemetry()["per_capability"]`.

    PYTHONPATH=src python -m benchmarks.grad_mcmc [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.apps.tsunami import TsunamiModel
from repro.core.fabric import EvaluationFabric, ModelBackend
from repro.uq.mcmc import (
    batched_logpost,
    batched_value_grad_logpost,
    effective_sample_size,
    ensemble_mala,
    ensemble_random_walk_metropolis,
)

TRUE_THETA = np.array([90.0, 2.5])
PRIOR = ((30.0, 150.0), (0.5, 4.0))  # x0 [km], amplitude [m]
NOISE_SD = np.array([0.5, 0.05, 0.5, 0.05])  # arrival [min], height [m]
LEVEL = {"level": 0}  # the coarse/smoothed SWE — the paper's workhorse level


def _pooled_min_ess(samples: np.ndarray) -> float:
    """Sum per-chain ESS over chains, then take the conservative min over
    dimensions ([K, n, d] -> scalar)."""
    K, _, d = samples.shape
    per_dim = [
        sum(effective_sample_size(samples[k, :, j]) for k in range(K))
        for j in range(d)
    ]
    return float(min(per_dim))


def _posterior_pieces(model: TsunamiModel, seed: int, data_config=LEVEL):
    """The shared tsunami toy posterior (synthetic data at TRUE_THETA +
    half-noise, box prior, Gaussian likelihood); `data_config` picks the
    level that generates the observations (surrogate_da uses the fine
    level)."""
    rng = np.random.default_rng(seed)
    data = np.asarray(model([list(TRUE_THETA)], data_config)[0])
    data = data + rng.standard_normal(4) * NOISE_SD * 0.5

    def logprior(th):
        ok = PRIOR[0][0] <= th[0] <= PRIOR[0][1] and PRIOR[1][0] <= th[1] <= PRIOR[1][1]
        return 0.0 if ok else -np.inf

    def loglik(obs):
        return float(-0.5 * np.sum(((np.asarray(obs) - data) / NOISE_SD) ** 2))

    data_j = jnp.asarray(data, jnp.float32)
    sd_j = jnp.asarray(NOISE_SD, jnp.float32)

    def grad_loglik(y):  # jax-traceable: rides INSIDE the fused wave
        return -(y - data_j) / sd_j**2

    return data, logprior, loglik, grad_loglik


def main(
    quick: bool = True,
    n_chains: int = 8,
    n_mala: int | None = None,
    seed: int = 3,
) -> dict:
    n_mala = n_mala or (40 if quick else 120)
    model = TsunamiModel()
    _, logprior, loglik, grad_loglik = _posterior_pieces(model, seed)
    prop_cov = np.diag([8.0**2, 0.25**2])  # the pre-tuned posterior scale

    rng = np.random.default_rng(11)
    x0s = np.stack(
        [rng.uniform(*PRIOR[0], n_chains), rng.uniform(*PRIOR[1], n_chains)], axis=1
    )

    # shared burn-in (not counted): both samplers start from the same
    # ensemble-RWM-burned states
    with EvaluationFabric(ModelBackend(model), cache_size=0) as fab_burn:
        lp_burn = batched_logpost(fab_burn, loglik, logprior, LEVEL)
        burn = ensemble_random_walk_metropolis(
            lp_burn, x0s, 12 if quick else 30, prop_cov, rng
        )
        x0s = burn.samples[:, -1, :]

    # ---- MALA: one fused value-and-grad wave per step ----------------------
    fab_m = EvaluationFabric(ModelBackend(model), cache_size=0)
    vg = batched_value_grad_logpost(
        fab_m, loglik, grad_loglik, logprior=logprior, config=LEVEL
    )
    vg(x0s)  # warm the fused jit path (compile outside the measured window)
    vg.reset()
    t0 = time.monotonic()
    res_m = ensemble_mala(
        vg, x0s, n_mala, 0.55, np.random.default_rng(100),
        precond=prop_cov, adapt_steps=max(10, n_mala // 4),
    )
    wall_m = time.monotonic() - t0
    tel_m = fab_m.telemetry()
    fab_m.shutdown()
    # the warm-up fused wave rode the same fabric: subtract it
    waves_m = tel_m["per_capability"]["value_and_gradient"]["waves"] - 1
    ess_m = _pooled_min_ess(res_m.samples)

    # ---- RWM at matched wall time: one evaluate wave per step --------------
    # evaluate waves are much cheaper than fused ones, so RWM gets MANY more
    # of them inside the same wall budget — the per-wave ESS comparison is
    # what the acceptance bar scores. Run in segments until the MALA wall is
    # consumed (a one-shot step-count estimate habitually undershoots
    # because prior-masked proposals make some waves nearly free).
    fab_r = EvaluationFabric(ModelBackend(model), cache_size=0)
    lp = batched_logpost(fab_r, loglik, logprior, LEVEL)
    lp(x0s)  # warm
    lp.reset()
    rwm_rng = np.random.default_rng(101)
    segments: list[np.ndarray] = []
    acc_frac = []
    xs = x0s
    seg = max(20, n_mala)
    t0 = time.monotonic()
    while time.monotonic() - t0 < wall_m:
        res_seg = ensemble_random_walk_metropolis(
            lp, xs, seg, (2.38**2 / 2) * prop_cov, rwm_rng
        )
        segments.append(res_seg.samples)
        acc_frac.append(res_seg.accept_rates)
        xs = res_seg.samples[:, -1, :]
    wall_r = time.monotonic() - t0
    tel_r = fab_r.telemetry()
    fab_r.shutdown()
    samples_r = np.concatenate(segments, axis=1)
    n_rwm = samples_r.shape[1]
    accept_r = float(np.mean(acc_frac))
    waves_r = tel_r["per_capability"]["evaluate"]["waves"] - 1  # warm wave
    ess_r = _pooled_min_ess(samples_r)

    ess_per_wave_m = ess_m / max(waves_m, 1)
    ess_per_wave_r = ess_r / max(waves_r, 1)
    ratio = ess_per_wave_m / max(ess_per_wave_r, 1e-12)
    out = {
        "n_chains": n_chains,
        "mala": {
            "steps": n_mala,
            "wall_s": round(wall_m, 2),
            "waves": int(waves_m),
            "accept_rate": round(res_m.accept_rate, 3),
            "step_size": round(res_m.final_step_size, 4),
            "ess": round(ess_m, 1),
            "ess_per_wave": round(ess_per_wave_m, 3),
            "points_evaluated": vg.points_evaluated,
            "evals_per_sec": round(vg.points_evaluated / wall_m, 2),
            "per_capability": tel_m["per_capability"],
        },
        "rwm": {
            "steps": n_rwm,
            "wall_s": round(wall_r, 2),
            "waves": int(waves_r),
            "accept_rate": round(accept_r, 3),
            "ess": round(ess_r, 1),
            "ess_per_wave": round(ess_per_wave_r, 3),
            "per_capability": tel_r["per_capability"],
        },
        "ess_per_wave_ratio": round(ratio, 2),
        "matched_wall": round(wall_r / max(wall_m, 1e-9), 2),
    }
    print(
        f"grad_mcmc: {n_chains} lockstep chains on the coarse tsunami "
        f"posterior\n  MALA {n_mala} fused waves in {wall_m:.1f}s: accept "
        f"{out['mala']['accept_rate']}, ESS {out['mala']['ess']} "
        f"({out['mala']['ess_per_wave']}/wave)\n  RWM {n_rwm} evaluate waves "
        f"in {wall_r:.1f}s (matched wall x{out['matched_wall']}): accept "
        f"{out['rwm']['accept_rate']}, ESS {out['rwm']['ess']} "
        f"({out['rwm']['ess_per_wave']}/wave)\n  => {out['ess_per_wave_ratio']}x "
        f"effective samples per wave (bar: >= 2x)"
    )
    return out


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the benchmark document (CI artifact)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    doc = {"schema": "grad-mcmc-v1", "created_unix": time.time(),
           **main(quick=not args.full)}
    if args.json:
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    # structural smoke assertions (CI): the capability split must be
    # visible and MALA must actually have run fused waves
    assert doc["mala"]["per_capability"]["value_and_gradient"]["waves"] > 0
    assert "gradient" not in doc["mala"]["per_capability"], (
        "fused path fell back to split evaluate+gradient waves"
    )
    if doc["ess_per_wave_ratio"] < 2.0:
        print(f"WARNING: ess/wave ratio {doc['ess_per_wave_ratio']} below the "
              "2x acceptance bar (short-chain ESS estimates are noisy; the "
              "canonical number lives in BENCH_results.json)")


if __name__ == "__main__":
    _cli()
