"""Paper §4.1 / Fig. 6: sparse-grid UQ of ship resistance R_T(F, D).

Reproduces the full SGMK workflow:
  1. nested sparse grids at w = 5, 10, 15 (triangular-Leja x beta-Leja knots),
     evaluating the L2-Sea analogue only at NEW points per level (nesting),
  2. the surrogate is sampled at 10^4 random (F, D) ~ (Triang, Beta) points,
  3. kernel density estimation of the PDF of R_T ('positive' support,
     bandwidth 0.1 — the paper's ksdensity call),
  4. the parallel speedup measurement of §4.1.3: 48 pool instances, eval cost
     scaled from the paper's ~30 s to keep the benchmark minutes-free.

Paper numbers for reference: 36/121/256 nested points, 290 s on 48 instances
vs 7680 s sequential -> speedup 26.5.
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.l2sea import DRAFT_RANGE, FROUDE_RANGE, L2SeaModel, make_inputs
from repro.core.fabric import EvaluationFabric
from repro.core.pool import ThreadedPool
from repro.uq.distributions import Beta, Triangular
from repro.uq.kde import kde
from repro.uq import sparse_grid as sg


def run(levels=(5, 10, 15), eval_cost_s: float = 0.0, n_instances: int = 48, n_pdf_samples: int = 10000):
    froude = Triangular(*FROUDE_RANGE)
    draft = Beta(10.0, 10.0, *DRAFT_RANGE)
    knots = [
        sg.knots_triangular_leja(*FROUDE_RANGE),
        sg.knots_beta_leja(10.0, 10.0, *DRAFT_RANGE),
    ]
    model = L2SeaModel(eval_cost_s=eval_cost_s)
    pool = ThreadedPool([L2SeaModel(eval_cost_s=eval_cost_s) for _ in range(n_instances)])
    # the UQ side talks to the fabric, not the pool (paper's LB separation)
    fabric = EvaluationFabric(pool, cache_size=1024)
    config = {"fidelity": 3}

    def f_batched(pts2d):
        return fabric.evaluate_batch(make_inputs(pts2d), config)

    rng = np.random.default_rng(0)
    sample = np.stack([froude.sample(rng, n_pdf_samples), draft.sample(rng, n_pdf_samples)], axis=1)

    rows = []
    prev = None
    total_evals = 0
    t_total0 = time.monotonic()
    for w in levels:
        S = sg.smolyak_grid(2, w, knots)
        Sr = sg.reduce_sparse_grid(S)
        n_before = total_evals
        t0 = time.monotonic()

        def counted(pts):
            nonlocal total_evals
            total_evals += len(pts)
            return f_batched(pts)

        vals = sg.evaluate_on_sparse_grid(counted, Sr, previous=prev)
        t_eval = time.monotonic() - t0
        prev = (Sr, vals)
        surr = sg.interpolate_on_sparse_grid(S, Sr, vals, sample)[:, 0]
        pdf, pts = kde(surr, support="positive", bandwidth=0.1)
        # surrogate accuracy at random validation points
        xq = np.stack([froude.sample(rng, 64), draft.sample(rng, 64)], axis=1)
        truth = model.evaluate_batch(
            np.asarray(make_inputs(xq), np.float32), config
        )[:, 0]
        pred = sg.interpolate_on_sparse_grid(S, Sr, vals, xq)[:, 0]
        rel = float(np.max(np.abs(pred - truth) / np.abs(truth)))
        rows.append(
            {
                "w": w,
                "grid_points": len(Sr.points),
                "new_evals": total_evals - n_before,
                "eval_wall_s": round(t_eval, 3),
                "surrogate_max_relerr": rel,
                "pdf_mode": float(pts[np.argmax(pdf)]),
            }
        )
        print(f"w={w:3d} points={len(Sr.points):4d} new_evals={total_evals - n_before:4d} "
              f"relerr={rel:.2e} pdf_mode={pts[np.argmax(pdf)]:.1f} kN")
    wall = time.monotonic() - t_total0
    seq = total_evals * max(eval_cost_s, 1e-9)
    fab = fabric.telemetry()
    fabric.shutdown()
    speedup = seq / wall if eval_cost_s else float("nan")
    print(f"total evals={total_evals} wall={wall:.1f}s sequential-equivalent={seq:.1f}s "
          f"speedup={speedup:.1f} (paper: 26.5 on 48 instances); "
          f"fabric waves={fab['waves']} cache hits={fab['cache_hits']}")
    return {"levels": rows, "total_evals": total_evals, "wall_s": wall, "speedup": speedup,
            "fabric": {k: fab[k] for k in ("waves", "points", "cache_hits", "cache_hit_rate")}}


def main(quick: bool = False):
    if quick:
        return run(levels=(3, 5), eval_cost_s=0.05, n_instances=8, n_pdf_samples=2000)
    return run(levels=(5, 10, 15), eval_cost_s=0.2, n_instances=48)


if __name__ == "__main__":
    main()
