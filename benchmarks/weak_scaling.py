"""Paper Fig. 5: weak scalability of the load-balanced pool.

Protocol (scaled to this container): model instances with a fixed synthetic
evaluation cost; the number of requested evaluations grows with the number
of instances (4 evals per instance); report wall time and parallel
efficiency per instance count. The paper's L2-Sea instances cost ~2.5 s; we
scale the cost down so the sweep finishes on one host (the pool overhead
being measured is the same queueing/dispatch code path).

`run_http` additionally measures the HTTP dispatch cost the paper's load
balancer pays per point: the same workload through per-point `/Evaluate`
round-trips vs the fabric's batched `/EvaluateBatch` fan-out, reporting the
round-trip reduction.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.client import HTTPModel
from repro.core.fabric import (
    EvaluationFabric,
    FabricRouter,
    HTTPBackend,
    ModelBackend,
    ThreadedBackend,
)
from repro.core.interface import JAXModel, Model
from repro.core.pool import ThreadedPool
from repro.core.server import serve_models
from repro.uq.mcmc import (
    batched_logpost,
    ensemble_random_walk_metropolis,
    random_walk_metropolis,
    run_chains,
)


class _FixedCostModel(Model):
    """Pure-latency model instance: isolates pool/queue overhead exactly as
    the paper's synthetic test isolates network/LB overhead (the model-side
    cost is held constant by always evaluating the same parameter)."""

    def __init__(self, cost_s: float):
        super().__init__("forward")
        self.cost_s = cost_s

    def get_input_sizes(self, c=None):
        return [16]

    def get_output_sizes(self, c=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, p, c=None):
        time.sleep(self.cost_s)
        return [[42.0]]


def run(eval_cost_s: float = 0.1, counts=(1, 2, 4, 8, 16, 32, 64), evals_per_instance: int = 4):
    rows = []
    for n in counts:
        instances = [_FixedCostModel(eval_cost_s) for _ in range(n)]
        pool = ThreadedPool(instances)
        theta = [0.33, -6.16] + [0.0] * 14
        n_evals = n * evals_per_instance
        t0 = time.monotonic()
        pool.evaluate([theta] * n_evals)
        wall = time.monotonic() - t0
        pool.shutdown()
        ideal = eval_cost_s * evals_per_instance
        rows.append(
            {
                "instances": n,
                "evaluations": n_evals,
                "wall_s": round(wall, 3),
                "ideal_s": round(ideal, 3),
                "efficiency": round(ideal / wall, 3),
            }
        )
        print(f"instances={n:3d} evals={n_evals:3d} wall={wall:6.3f}s "
              f"ideal={ideal:.3f}s efficiency={ideal / wall:.3f}")
    return rows


def run_http(
    n_servers: int = 4,
    n_points: int = 64,
    eval_cost_s: float = 0.005,
    base_port: int = 46310,
):
    """Per-point `/Evaluate` vs batched `/EvaluateBatch` round-trips for the
    same workload over the same servers (the §3 LB hop, minus k8s)."""
    servers = []
    urls = []
    thetas = np.tile(np.linspace(0.0, 1.0, n_points)[:, None], (1, 16))
    try:
        for i in range(n_servers):
            server, _ = serve_models([_FixedCostModel(eval_cost_s)], base_port + i, background=True)
            servers.append(server)
            urls.append(f"http://127.0.0.1:{base_port + i}")
        # per-point path: one /Evaluate round-trip per point (ThreadedPool of
        # HTTP clients — the seed's only HTTP dispatch mode)
        clients = [HTTPModel(u) for u in urls]
        for c in clients:
            c.round_trips = 0  # ignore handshake requests
        pool = ThreadedPool(clients)
        t0 = time.monotonic()
        pool.evaluate(thetas)
        wall_pp = time.monotonic() - t0
        pool.shutdown()
        rt_per_point = sum(c.round_trips for c in clients)

        # batched path: the fabric fans /EvaluateBatch out across servers
        clients_b = [HTTPModel(u) for u in urls]
        for c in clients_b:
            c.round_trips = 0
        fabric = EvaluationFabric(HTTPBackend(clients_b), cache_size=0)
        t0 = time.monotonic()
        fabric.evaluate_batch(thetas)
        wall_b = time.monotonic() - t0
        rt_batched = sum(c.round_trips for c in clients_b)
        fabric.shutdown()
    finally:
        for s in servers:
            s.shutdown()
    ratio = rt_per_point / max(rt_batched, 1)
    print(f"HTTP round-trips for {n_points} points on {n_servers} servers: "
          f"per-point={rt_per_point} batched={rt_batched} "
          f"({ratio:.1f}x fewer), wall {wall_pp:.2f}s -> {wall_b:.2f}s")
    return {
        "n_points": n_points,
        "n_servers": n_servers,
        "round_trips_per_point_path": rt_per_point,
        "round_trips_batched_path": rt_batched,
        "round_trip_reduction": ratio,
        "wall_per_point_s": round(wall_pp, 3),
        "wall_batched_s": round(wall_b, 3),
    }


def _compute_model() -> JAXModel:
    """Compute-bound synthetic model (an iterated map XLA cannot fold away):
    per-point cost is real device time, so the lockstep comparison measures
    dispatch amortization, not sleep arithmetic."""
    import jax
    import jax.numpy as jnp

    def fn(th):
        base = jnp.sum((th - 0.3) ** 2)

        def body(i, z):
            return 0.999 * z + 0.001 * jnp.cos(i * 0.01 + z)

        return jnp.atleast_1d(jax.lax.fori_loop(0, 800, body, base))

    return JAXModel(fn, n_inputs=2, n_outputs=1)


def run_lockstep(n_chains: int = 16, n_steps: int = 50):
    """K MCMC chains, two dispatch disciplines over the SAME native-batch
    model: (before) K threads, one fabric submit per proposal — waves only
    form when the collector happens to catch concurrent chains; (after) the
    lockstep ensemble sampler — every step is ONE perfectly-filled K-point
    wave. Reports evals/sec and wave fill for both."""
    rng = np.random.default_rng(5)
    x0s = rng.standard_normal((n_chains, 2)) * 0.5
    cov = 0.6 * np.eye(2)
    evals = n_chains * (n_steps + 1)

    # -- before: threaded chains, per-point submits --------------------------
    fabric_pp = EvaluationFabric(ModelBackend(_compute_model()), cache_size=0)
    fabric_pp.submit(x0s[0]).result()  # warm the jit

    def make_chain(i, fab):
        lp = lambda th: -0.5 * float(fab.submit(th).result()[0])
        return random_walk_metropolis(
            lp, x0s[i], n_steps, cov, np.random.default_rng(100 + i)
        )

    t0 = time.monotonic()
    run_chains(make_chain, n_chains, parallel=True, fabric=fabric_pp)
    wall_pp = time.monotonic() - t0
    tel_pp = fabric_pp.telemetry()
    fabric_pp.shutdown()

    # -- after: lockstep ensemble, one wave per step -------------------------
    fabric_ls = EvaluationFabric(
        ModelBackend(_compute_model()), cache_size=0, max_batch=n_chains
    )
    lp_batch = batched_logpost(fabric_ls, lambda y: -0.5 * float(y[0]))
    lp_batch(x0s)  # warm the batch jit
    t0 = time.monotonic()
    ensemble_random_walk_metropolis(lp_batch, x0s, n_steps, cov, rng)
    wall_ls = time.monotonic() - t0
    tel_ls = fabric_ls.telemetry()
    fabric_ls.shutdown()

    out = {
        "n_chains": n_chains,
        "n_steps": n_steps,
        "threaded_evals_per_sec": round(evals / wall_pp, 1),
        "ensemble_evals_per_sec": round(evals / wall_ls, 1),
        "speedup": round(wall_pp / wall_ls, 2),
        "threaded_wave_fill": round(tel_pp["mean_wave_size"] / n_chains, 3),
        "ensemble_wave_fill": round(tel_ls["mean_wave_size"] / n_chains, 3),
    }
    print(f"lockstep ensemble vs {n_chains} threaded chains ({evals} evals): "
          f"{out['threaded_evals_per_sec']}/s (wave fill "
          f"{out['threaded_wave_fill']:.0%}) -> {out['ensemble_evals_per_sec']}/s "
          f"(fill {out['ensemble_wave_fill']:.0%}), {out['speedup']}x")
    return out


def measure_router_policies(
    make_pools,
    thetas: np.ndarray,
    n_points: int,
    n_waves: int,
    config: dict | None = None,
    warmup_waves: int = 2,
) -> dict:
    """Shared router-measurement harness: run the same waves under the
    round-robin baseline and the latency-aware policy over pools built
    FRESH per policy by `make_pools()`. The warm-up waves teach the EWMA
    the per-backend service times, then `reset_stats` so the reported
    shares/imbalance are the steady state, not the cold probe. `thetas`
    must hold `n_points * (n_waves + warmup_waves)` rows."""
    out = {}
    for policy in ("round_robin", "latency"):
        router = FabricRouter(
            [ThreadedBackend(p) for p in make_pools()], policy=policy
        )
        fab = EvaluationFabric(router, cache_size=0)
        for w in range(warmup_waves):
            fab.evaluate_batch(thetas[w * n_points:(w + 1) * n_points], config)
        router.reset_stats()
        t0 = time.monotonic()
        for w in range(warmup_waves, n_waves + warmup_waves):
            fab.evaluate_batch(thetas[w * n_points:(w + 1) * n_points], config)
        wall = time.monotonic() - t0
        tel = fab.telemetry()
        out[policy] = {
            "imbalance": tel["router_imbalance"],
            "last_imbalance": router.router_stats["last_imbalance"],
            "backend_share": tel["backend_share"],
            "evals_per_sec": round(n_points * n_waves / wall, 2),
        }
        fab.shutdown()
    return out


def run_router(
    n_points: int = 32,
    n_waves: int = 4,
    eval_cost_s: float = 0.02,
    slow_factor: float = 4.0,
):
    """Heterogeneous pool balancing: two sub-clusters of 2 instances each,
    one `slow_factor`x slower per evaluation. The same waves run under
    round-robin (static even split — what a config-file share list gives
    you) and the router's latency-aware policy (EWMA service time + JSQ);
    report steady-state imbalance factor and throughput for both."""
    rng = np.random.default_rng(3)
    thetas = rng.standard_normal((n_points * (n_waves + 2), 16))
    out = measure_router_policies(
        lambda: [
            ThreadedPool([_FixedCostModel(eval_cost_s) for _ in range(2)]),
            ThreadedPool(
                [_FixedCostModel(eval_cost_s * slow_factor) for _ in range(2)]
            ),
        ],
        thetas, n_points, n_waves,
    )
    print(f"router, [1x, {slow_factor:g}x-slower] pools, {n_waves} waves x "
          f"{n_points} pts: round_robin imbalance "
          f"{out['round_robin']['imbalance']} -> latency "
          f"{out['latency']['imbalance']} (shares "
          f"{out['latency']['backend_share']}, "
          f"{out['round_robin']['evals_per_sec']} -> "
          f"{out['latency']['evals_per_sec']} evals/s)")
    return out


def main(quick: bool = False):
    counts = (1, 2, 4, 8, 16) if quick else (1, 2, 4, 8, 16, 32, 64)
    rows = run(eval_cost_s=0.05 if quick else 0.1, counts=counts)
    http = run_http(n_servers=2 if quick else 4, n_points=32 if quick else 64)
    lockstep = run_lockstep(n_chains=8 if quick else 16, n_steps=30 if quick else 50)
    router = run_router(n_points=16 if quick else 32, n_waves=3 if quick else 4)
    return {"weak_scaling": rows, "http_round_trips": http,
            "lockstep": lockstep, "router": router}


if __name__ == "__main__":
    main()
