"""Paper Fig. 5: weak scalability of the load-balanced pool.

Protocol (scaled to this container): model instances with a fixed synthetic
evaluation cost; the number of requested evaluations grows with the number
of instances (4 evals per instance); report wall time and parallel
efficiency per instance count. The paper's L2-Sea instances cost ~2.5 s; we
scale the cost down so the sweep finishes on one host (the pool overhead
being measured is the same queueing/dispatch code path).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.interface import Model
from repro.core.pool import ThreadedPool


class _FixedCostModel(Model):
    """Pure-latency model instance: isolates pool/queue overhead exactly as
    the paper's synthetic test isolates network/LB overhead (the model-side
    cost is held constant by always evaluating the same parameter)."""

    def __init__(self, cost_s: float):
        super().__init__("forward")
        self.cost_s = cost_s

    def get_input_sizes(self, c=None):
        return [16]

    def get_output_sizes(self, c=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, p, c=None):
        time.sleep(self.cost_s)
        return [[42.0]]


def run(eval_cost_s: float = 0.1, counts=(1, 2, 4, 8, 16, 32, 64), evals_per_instance: int = 4):
    rows = []
    for n in counts:
        instances = [_FixedCostModel(eval_cost_s) for _ in range(n)]
        pool = ThreadedPool(instances)
        theta = [0.33, -6.16] + [0.0] * 14
        n_evals = n * evals_per_instance
        t0 = time.monotonic()
        pool.evaluate([theta] * n_evals)
        wall = time.monotonic() - t0
        pool.shutdown()
        ideal = eval_cost_s * evals_per_instance
        rows.append(
            {
                "instances": n,
                "evaluations": n_evals,
                "wall_s": round(wall, 3),
                "ideal_s": round(ideal, 3),
                "efficiency": round(ideal / wall, 3),
            }
        )
        print(f"instances={n:3d} evals={n_evals:3d} wall={wall:6.3f}s "
              f"ideal={ideal:.3f}s efficiency={ideal / wall:.3f}")
    return rows


def main(quick: bool = False):
    counts = (1, 2, 4, 8, 16) if quick else (1, 2, 4, 8, 16, 32, 64)
    return run(eval_cost_s=0.05 if quick else 0.1, counts=counts)


if __name__ == "__main__":
    main()
