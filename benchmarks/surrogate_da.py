"""Surrogate-accelerated (three-stage) delayed acceptance on the tsunami
hierarchy: GP screen below the coarse level, trained ONLINE from fabric
cache traffic.

Two runs of lockstep `ensemble_mlda` on the 2-level tsunami posterior
(coarse/smoothed SWE proposing for the fully-resolved SWE), identical
warm-up and budgets:

  * **two-stage baseline** — every coarse subchain proposal pays a coarse
    wave (the PR-3 sampler);
  * **three-stage surrogate** — an `OnlineGP` screen, trained from the
    warm-up's own coarse waves through the fabric training tap
    (`record_observer` -> `SurrogateStore`; ZERO extra model evaluations)
    and frozen before measurement, scores every proposal first; only
    stage-1 survivors pay the coarse wave, and the stage-2 DA correction
    keeps the posterior exact no matter how wrong the GP is.

Acceptance bar: >= 2x reduction in coarse-model evaluations per unit of
fine-level ESS, with the screen's traffic visible in the fabric telemetry
(`surrogate_screened`, `screen_pass_rate`).

    PYTHONPATH=src python -m benchmarks.surrogate_da [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.grad_mcmc import PRIOR, _pooled_min_ess, _posterior_pieces
from repro.apps.tsunami import TsunamiModel
from repro.core.fabric import EvaluationFabric, ModelBackend
from repro.uq.mcmc import batched_logpost, ensemble_random_walk_metropolis
from repro.uq.mlda import ensemble_mlda
from repro.uq.surrogate import SurrogateScreen

L0, L1 = {"level": 0}, {"level": 1}


def main(
    quick: bool = True,
    n_chains: int = 8,
    n_fine: int | None = None,
    n_warm: int | None = None,
    sub: int = 5,
    seed: int = 3,
) -> dict:
    n_fine = n_fine or (40 if quick else 100)
    n_warm = n_warm or (40 if quick else 80)
    model = TsunamiModel()
    # the shared tsunami toy posterior, with the DATA generated at the fine
    # level (this benchmark's posterior lives on the 2-level hierarchy)
    _, logprior, loglik, _ = _posterior_pieces(model, seed, data_config=L1)
    prop_cov = np.diag([8.0**2, 0.25**2])  # the pre-tuned posterior scale

    def run(surrogate_on: bool) -> dict:
        fab = EvaluationFabric(ModelBackend(model), cache_size=8192)
        fab.label_config(L0, "coarse")
        fab.label_config(L1, "fine")
        screen = None
        if surrogate_on:
            screen = SurrogateScreen.from_fabric(
                fab, target=lambda th, y: loglik(y), config=L0,
                logprior=logprior,
                window=256, min_train=48, hyper_iters=120, refit_every=64,
            )
        # identical warm-up for both runs: lockstep RWM on the coarse
        # posterior — in the surrogate run, these very waves ALSO train the
        # GP through the fabric tap (no extra evaluations)
        rng = np.random.default_rng(11)
        x0s = np.stack(
            [rng.uniform(*PRIOR[0], n_chains), rng.uniform(*PRIOR[1], n_chains)],
            axis=1,
        )
        lp0 = batched_logpost(fab, loglik, logprior, L0)
        burn = ensemble_random_walk_metropolis(
            lp0, x0s, n_warm, (2.38**2 / 2) * prop_cov, rng
        )
        xs = burn.samples[:, -1, :]
        if screen is not None:
            assert screen.active, (
                f"warm-up traffic ({screen.store.n_points} points) did not "
                "reach min_train — raise n_warm"
            )
            screen.freeze()  # measured run uses a fixed, time-homogeneous screen
        pre = {k: dict(v) for k, v in fab.telemetry()["per_label"].items()}
        t0 = time.monotonic()
        res = ensemble_mlda(
            None, xs, n_fine, [sub], prop_cov, np.random.default_rng(100),
            fabric=fab, loglik=loglik, logprior=logprior,
            level_configs=[L0, L1], surrogate=screen,
        )
        wall = time.monotonic() - t0
        tel = fab.telemetry()
        fab.shutdown()
        coarse_pts = tel["per_label"]["coarse"]["points"] - pre["coarse"]["points"]
        fine_pts = tel["per_label"]["fine"]["points"] - pre["fine"]["points"]
        ess = _pooled_min_ess(res.samples)
        out = {
            "wall_s": round(wall, 2),
            "coarse_model_points": int(coarse_pts),
            "fine_model_points": int(fine_pts),
            "coarse_evals_requested": int(res.evals_per_level[0]),
            "accept_rates": [round(a, 3) for a in res.accept_rates],
            "n_waves": int(res.n_waves),
            "ess": round(ess, 1),
            "coarse_points_per_ess": round(coarse_pts / max(ess, 1e-9), 2),
            "posterior_mean": [round(m, 3) for m in res.samples_flat.mean(0)],
            "coarse_evals_per_sec": round(coarse_pts / max(wall, 1e-9), 2),
        }
        if screen is not None:
            s = screen.stats()
            out["screen"] = {
                "screened": s["screened"],
                "passed": s["passed"],
                "pass_rate": (round(s["pass_rate"], 3)
                              if s["pass_rate"] is not None else None),
                "skipped": s["skipped"],
                "gp_window": s["gp"]["n"],
                "gp_hyper_fits": s["gp"]["hyper_fits"],
                "store_points": s["store"]["points_observed"],
            }
            out["screen_telemetry"] = {
                "surrogate_screened": tel["surrogate_screened"],
                "surrogate_passed": tel["surrogate_passed"],
                "screen_pass_rate": round(tel["screen_pass_rate"], 3),
            }
        return out

    base = run(surrogate_on=False)
    surr = run(surrogate_on=True)
    reduction = base["coarse_points_per_ess"] / max(
        surr["coarse_points_per_ess"], 1e-9
    )
    out = {
        "n_chains": n_chains,
        "n_fine_steps": n_fine,
        "subsampling": sub,
        "baseline_two_stage": base,
        "surrogate_three_stage": surr,
        "coarse_evals_per_ess_reduction": round(reduction, 2),
    }
    print(
        f"surrogate_da: {n_chains} lockstep chains, {n_fine} fine steps, "
        f"subchain {sub}\n  two-stage:   {base['coarse_model_points']} coarse "
        f"evals, ESS {base['ess']} -> {base['coarse_points_per_ess']} "
        f"evals/ESS in {base['wall_s']}s\n  three-stage: "
        f"{surr['coarse_model_points']} coarse evals, ESS {surr['ess']} -> "
        f"{surr['coarse_points_per_ess']} evals/ESS in {surr['wall_s']}s "
        f"(screen pass rate {surr['screen']['pass_rate']})\n  => "
        f"{out['coarse_evals_per_ess_reduction']}x fewer coarse evals per "
        f"unit ESS (bar: >= 2x)"
    )
    return out


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the benchmark document (CI artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: structural assertions, no perf bar")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        doc = main(quick=True, n_chains=6, n_fine=12, n_warm=30)
    else:
        doc = main(quick=not args.full)
    doc = {"schema": "surrogate-da-v1", "created_unix": time.time(), **doc}
    if args.json:
        # write BEFORE the assertions: a failing smoke leaves exactly the
        # telemetry the investigation needs
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    surr = doc["surrogate_three_stage"]
    # structural smoke assertions (CI): the screen must have trained from
    # tap traffic alone, actually screened, and surfaced in the telemetry
    assert surr["screen"]["store_points"] > 0
    assert surr["screen"]["screened"] > 0
    assert 0.0 < surr["screen_telemetry"]["screen_pass_rate"] < 1.0
    assert surr["coarse_model_points"] < doc["baseline_two_stage"]["coarse_model_points"]
    if doc["coarse_evals_per_ess_reduction"] < 2.0 and not args.smoke:
        print(f"WARNING: coarse-evals-per-ESS reduction "
              f"{doc['coarse_evals_per_ess_reduction']} below the 2x bar "
              "(short-chain ESS is noisy; the canonical number lives in "
              "BENCH_results.json)")


if __name__ == "__main__":
    _cli()
