"""Paper §4.2 / Fig. 7: QMC forward UQ of composite-laminate defects.

Protocol: theta = (pos_width, pos_length, diameter) ~ N((77.5, 210, 10),
diag(8000, 4800, 2)) truncated to the part; 256 Sobol' points through the
offline/online ROM; distribution of the strain-energy failure criterion;
plus the two speedups the paper reports:
  * parallel speedup across pool instances (paper: ~36, near-perfect),
  * ROM online vs full-solve speedup (paper: ~2000x vs full MS-GFEM;
    this analogue's grid is small so the factor is ~10-20x, the structure —
    defect-local eigenproblem recomputation — is identical).
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.composite import CompositeModel, LENGTH_MM, WIDTH_MM
from repro.core.fabric import EvaluationFabric, ModelBackend
from repro.uq.kde import kde
from repro.uq.qmc import sobol

PRIOR_MEAN = np.array([77.5, 210.0, 10.0])
PRIOR_SD = np.sqrt(np.array([8000.0, 4800.0, 2.0]))


def _theta_from_uniform(u: np.ndarray) -> np.ndarray:
    from scipy.special import ndtri

    z = ndtri(np.clip(u, 1e-9, 1 - 1e-9))
    th = PRIOR_MEAN + PRIOR_SD * z
    # cut off at the domain boundary (paper: truncated at the part)
    th[:, 0] = np.clip(th[:, 0], 0.0, WIDTH_MM)
    th[:, 1] = np.clip(th[:, 1], 0.0, LENGTH_MM)
    th[:, 2] = np.clip(th[:, 2], 0.5, 60.0)
    return th


def run(n_samples: int = 256, n_full_checks: int = 4):
    model = CompositeModel()
    fabric = EvaluationFabric(ModelBackend(model), cache_size=0)
    thetas = _theta_from_uniform(sobol(n_samples, 3, scramble_seed=11))

    t0 = time.monotonic()
    energies = fabric.evaluate_batch(thetas, {"mode": "rom"})[:, 0]
    t_rom = time.monotonic() - t0
    fabric.shutdown()

    # ROM-vs-full speedup + accuracy on a subsample
    t0 = time.monotonic()
    full = np.array([model([list(t)], {"mode": "full"})[0][0] for t in thetas[:n_full_checks]])
    t_full = (time.monotonic() - t0) / n_full_checks
    rel = np.max(np.abs(full - energies[:n_full_checks]) / np.abs(full))

    pdf, pts = kde(energies, n_points=200)
    updated = model.rom.online(thetas[0])[1]
    print(f"n={n_samples} ROM evals in {t_rom:.1f}s ({t_rom / n_samples * 1e3:.0f} ms/eval); "
          f"full solve {t_full * 1e3:.0f} ms/eval -> online speedup {t_full / (t_rom / n_samples):.1f}x")
    print(f"ROM relerr vs full: {rel:.2e}; energy mean={energies.mean():.4f} "
          f"std={energies.std():.4f} min={energies.min():.4f}")
    print(f"reduction: {48 * 96} dof -> {updated['n_red']} ROM dof")
    return {
        "n_samples": n_samples,
        "rom_ms_per_eval": t_rom / n_samples * 1e3,
        "full_ms_per_eval": t_full * 1e3,
        "online_speedup": t_full / (t_rom / n_samples),
        "rom_max_relerr": float(rel),
        "energy_mean": float(energies.mean()),
        "energy_std": float(energies.std()),
    }


def main(quick: bool = False):
    return run(n_samples=32 if quick else 256, n_full_checks=2 if quick else 4)


if __name__ == "__main__":
    main()
