"""Multi-tenant service benchmark: 8 mixed campaigns on ONE fabric.

Four phases over the same sleepy quadratic model fleet:

1. **sequential baseline** — the 8 campaigns (1 high-priority MCMC,
   3 normal MCMC, 2 QMC, 2 MLDA) run one after another through a fresh
   `UQService`; total dispatched points / wall is the reference rate.
2. **concurrent** — the same 8 campaigns run simultaneously from 8
   threads through one service. Fair-share scheduling must not tax
   throughput: the concurrent rate must stay >= `min_ratio` x sequential
   (it is normally a multiple — concurrent waves overlap on the pool).
   The two QMC tenants evaluate the same Sobol' points and both declare
   the config shareable, so the second rides the first's cache rows
   (`shared_hits > 0`); the MCMC tenants run IDENTICAL chains but stay in
   private namespaces, so their cross-tenant hits must be ZERO (isolation).
3. **priority latency** — the high-priority tenant's wave p99 is measured
   unloaded (alone on a fresh service), then again while 4 low-priority
   flood tenants saturate every dispatch slot. Strict tier precedence must
   hold the overloaded p99 within `max_p99_ratio` x the unloaded p99.
4. **admission + budget** — a quota-capped tenant bursts from 6 threads:
   some waves shed with `Overloaded` (backpressure, counted), and every
   wave that was NOT shed must return bit-correct results (zero corrupted
   or lost). A budget-capped MCMC campaign must stop cleanly mid-run with
   `terminated="budget"` and a valid truncated chain.

    PYTHONPATH=src python -m benchmarks.multi_tenant [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from repro.core.fabric import EvaluationFabric, Overloaded, ThreadedBackend
from repro.core.interface import Model
from repro.core.pool import ThreadedPool
from repro.core.service import UQService
from repro.uq.mcmc import batched_logpost, ensemble_random_walk_metropolis
from repro.uq.mlda import ensemble_mlda
from repro.uq.qmc import cub_qmc_sobol


class _SleepQuadratic(Model):
    """out = sum((theta - shift)^2) with a per-call sleep; shift -0.5 on
    the MLDA coarse level, 1.0 otherwise, so loglik(y) = -y/2 targets the
    analytic N(1, I) at the fine level."""

    def __init__(self, cost_s: float):
        super().__init__("forward")
        self.cost_s = cost_s

    def get_input_sizes(self, c=None):
        return [2]

    def get_output_sizes(self, c=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, p, c=None):
        if self.cost_s:
            time.sleep(self.cost_s)
        shift = -0.5 if (c or {}).get("level") == 0 else 1.0
        th = np.asarray(p[0], float)
        return [[float(((th - shift) ** 2).sum())]]


def _expected(thetas, config=None) -> np.ndarray:
    shift = -0.5 if (config or {}).get("level") == 0 else 1.0
    return ((np.atleast_2d(np.asarray(thetas, float)) - shift) ** 2).sum(1)


def _mk_service(cost_s: float, width: int = 4, **kw) -> UQService:
    fabric = EvaluationFabric(
        ThreadedBackend(ThreadedPool([_SleepQuadratic(cost_s) for _ in range(width)])),
        cache_size=8192,
    )
    kw.setdefault("max_concurrent_waves", width)
    return UQService(fabric, **kw)


def _LOGLIK(y):
    return -0.5 * float(y[0])


def _mcmc_job(camp, n_steps: int, K: int = 8, seed: int = 3):
    lp = batched_logpost(camp, _LOGLIK)
    x0s = np.random.default_rng(seed).standard_normal((K, 2)) * 0.3 + 1.0
    return ensemble_random_walk_metropolis(
        lp, x0s, n_steps, 0.5 * np.eye(2), np.random.default_rng(seed + 1)
    )


def _qmc_job(camp, n_max: int, seed: int = 11):
    # abs_tol=0 never converges: the point count is fixed by n_max, so two
    # tenants with the same seed trace IDENTICAL Sobol' points
    return cub_qmc_sobol(camp, dim=2, abs_tol=0.0, n_init=32,
                         n_max=n_max, replications=4, seed=seed)


def _mlda_job(camp, n_samples: int, K: int = 8, seed: int = 5):
    x0s = np.random.default_rng(7).standard_normal((K, 2)) * 0.3 + 1.0
    return ensemble_mlda(
        None, x0s, n_samples, [3], 0.7 * np.eye(2),
        np.random.default_rng(seed), fabric=camp, loglik=_LOGLIK,
        level_configs=[{"level": 0}, {"level": 1}],
    )


def _campaign_mix(service: UQService, n_steps: int, n_samples: int, n_max: int):
    """(tenant, thunk) pairs for the 8-campaign mix. MCMC tenants share a
    SEED (identical traffic) but not a namespace; QMC tenants share both."""
    share = dict(share_configs=[None])
    jobs = [
        ("hi", "high", lambda c: _mcmc_job(c, n_steps, K=32, seed=21), {}),
        ("mcmc-0", "normal", lambda c: _mcmc_job(c, n_steps, seed=3), {}),
        ("mcmc-1", "normal", lambda c: _mcmc_job(c, n_steps, seed=3), {}),
        ("mcmc-2", "normal", lambda c: _mcmc_job(c, n_steps, seed=3), {}),
        ("qmc-0", "low", lambda c: _qmc_job(c, n_max), share),
        ("qmc-1", "low", lambda c: _qmc_job(c, n_max), share),
        ("mlda-0", "normal", lambda c: _mlda_job(c, n_samples), {}),
        ("mlda-1", "low", lambda c: _mlda_job(c, n_samples), {}),
    ]

    def run_one(spec):
        tenant, priority, job, kw = spec
        with service.open_campaign(tenant, priority=priority, **kw) as camp:
            return job(camp)

    return jobs, run_one


def main(quick: bool = True, smoke: bool = False) -> dict:
    n_steps = 16 if smoke else (30 if quick else 80)
    n_samples = 10 if smoke else (16 if quick else 40)
    n_max = 64 if smoke else 128
    cost_s = 0.002 if smoke else 0.003
    # smoke runs on loaded CI runners; quick/full assert the paper-level bar
    min_ratio = 0.5 if smoke else 0.9
    max_p99_ratio = 3.0 if smoke else 2.0

    # -- phase 1: the 8 campaigns, one at a time ------------------------------
    service = _mk_service(cost_s)
    jobs, run_one = _campaign_mix(service, n_steps, n_samples, n_max)
    t0 = time.monotonic()
    try:
        for spec in jobs:
            run_one(spec)
        wall_seq = time.monotonic() - t0
        seq_points = service.fabric.stats["points"]
    finally:
        service.close()
        service.fabric.shutdown()
    seq_rate = seq_points / wall_seq

    # -- phase 2: the same 8 campaigns, concurrently --------------------------
    service = _mk_service(cost_s)
    jobs, run_one = _campaign_mix(service, n_steps, n_samples, n_max)
    t0 = time.monotonic()
    try:
        with ThreadPoolExecutor(max_workers=len(jobs)) as ex:
            list(ex.map(run_one, jobs))
        wall_conc = time.monotonic() - t0
        conc_points = service.fabric.stats["points"]
        tel = service.telemetry()
    finally:
        service.close()
        service.fabric.shutdown()
    conc_rate = conc_points / wall_conc
    ratio = conc_rate / seq_rate
    per_tenant = tel["fabric_per_tenant"]
    shared_hits = per_tenant.get("qmc-1", {}).get("shared_hits_taken", 0) + \
        per_tenant.get("qmc-0", {}).get("shared_hits_taken", 0)
    shared_given = sum(v.get("shared_hits_given", 0) for v in per_tenant.values())
    # isolation: the three normal MCMC tenants traced IDENTICAL chains in
    # PRIVATE namespaces — a single cross-tenant hit would be a leak
    mcmc_leaks = sum(
        per_tenant.get(t, {}).get("shared_hits_taken", 0)
        for t in ("mcmc-0", "mcmc-1", "mcmc-2")
    )
    assert shared_hits > 0, "opt-in QMC tenants shared no cache rows"
    assert mcmc_leaks == 0, f"private MCMC namespaces leaked {mcmc_leaks} hits"

    # -- phase 3: high-priority p99, unloaded vs overloaded -------------------
    def _hi_p99(service):
        with service.open_campaign("hi", priority="high") as camp:
            lp = batched_logpost(camp, _LOGLIK)
            x0s = np.random.default_rng(21).standard_normal((32, 2)) * 0.3 + 1.0
            ensemble_random_walk_metropolis(
                lp, x0s, n_steps, 0.5 * np.eye(2), np.random.default_rng(22)
            )
        return service.telemetry()["tenants"]["hi"]["p99_wave_s"]

    service = _mk_service(cost_s)
    try:
        p99_unloaded = _hi_p99(service)
    finally:
        service.close()
        service.fabric.shutdown()

    service = _mk_service(cost_s)
    stop = threading.Event()
    shed_flood = [0]

    def flood(i):
        # low-priority floods keep every dispatch slot hot with SMALL waves;
        # strict tier precedence should bound the high tenant's extra wait
        # to one in-flight flood wave's residual
        rng = np.random.default_rng(100 + i)
        with service.open_campaign(f"flood-{i}", priority="low") as camp:
            while not stop.is_set():
                try:
                    camp.evaluate_batch(rng.standard_normal((4, 2)))
                except Overloaded:
                    shed_flood[0] += 1
                    time.sleep(cost_s)

    flood_threads = [threading.Thread(target=flood, args=(i,), daemon=True)
                     for i in range(4)]
    try:
        for t in flood_threads:
            t.start()
        time.sleep(10 * cost_s)  # let the floods saturate the slots first
        p99_overloaded = _hi_p99(service)
    finally:
        stop.set()
        for t in flood_threads:
            t.join(timeout=10)

    p99_ratio = p99_overloaded / max(p99_unloaded, 1e-9)

    # -- phase 4a: admission control sheds, survivors stay correct ------------
    sheds = [0]
    corrupt = [0]
    ok_waves = [0]
    with service.open_campaign("burst", priority="normal",
                               max_inflight_points=12) as camp:
        def burst(i):
            rng = np.random.default_rng(200 + i)
            for _ in range(6):
                thetas = rng.standard_normal((8, 2))
                try:
                    ys = camp.evaluate_batch(thetas)
                except Overloaded:
                    sheds[0] += 1
                    continue
                ok_waves[0] += 1
                if not np.allclose(np.asarray(ys).ravel(), _expected(thetas)):
                    corrupt[0] += 1

        with ThreadPoolExecutor(max_workers=6) as ex:
            list(ex.map(burst, range(6)))
    assert sheds[0] > 0, "the burst never tripped admission control"
    assert corrupt[0] == 0, f"{corrupt[0]} admitted waves returned wrong data"

    # -- phase 4b: budget runs dry -> clean truncated chain -------------------
    K, budget_steps = 8, 10
    with service.open_campaign("budget-demo", budget=K * budget_steps) as camp:
        res = _mcmc_job(camp, 4 * budget_steps, K=K)
        budget_left = camp.budget_remaining
    service_tel = service.telemetry()
    service.close()
    service.fabric.shutdown()
    assert res.terminated == "budget", "budgeted campaign did not stop cleanly"
    assert res.samples.shape[1] < 4 * budget_steps
    assert np.isfinite(res.samples).all()

    doc = {
        "schema": "multi-tenant-v1",
        "created_unix": time.time(),
        "mode": "smoke" if smoke else ("quick" if quick else "full"),
        "throughput": {
            "sequential_evals_per_sec": round(seq_rate, 1),
            "concurrent_evals_per_sec": round(conc_rate, 1),
            "ratio": round(ratio, 3),
            "min_ratio": min_ratio,
            "sequential_wall_s": round(wall_seq, 3),
            "concurrent_wall_s": round(wall_conc, 3),
            "points": conc_points,
        },
        "cache": {
            "shared_hits_taken": int(shared_hits),
            "shared_hits_given": int(shared_given),
            "private_mcmc_leaks": int(mcmc_leaks),
        },
        "priority": {
            "p99_unloaded_s": round(p99_unloaded, 5),
            "p99_overloaded_s": round(p99_overloaded, 5),
            "p99_ratio": round(p99_ratio, 3),
            "max_p99_ratio": max_p99_ratio,
            "flood_sheds": shed_flood[0],
        },
        "admission": {
            "sheds": sheds[0],
            "ok_waves": ok_waves[0],
            "corrupted": corrupt[0],
        },
        "budget": {
            "budget_points": K * budget_steps,
            "steps_completed": int(res.samples.shape[1]),
            "terminated": res.terminated,
            "budget_remaining": budget_left,
        },
        "scheduler": {
            t: {k: v for k, v in d.items()
                if k in ("priority", "granted_waves", "shed", "aged_grants")}
            for t, d in service_tel["tenants"].items()
        },
    }
    print(
        f"multi-tenant: concurrent {conc_rate:.0f}/s vs sequential "
        f"{seq_rate:.0f}/s (ratio {ratio:.2f}, floor {min_ratio}); "
        f"hi p99 {p99_overloaded * 1e3:.1f}ms overloaded vs "
        f"{p99_unloaded * 1e3:.1f}ms unloaded (ratio {p99_ratio:.2f}, "
        f"cap {max_p99_ratio}); {shared_hits} shared hits, "
        f"{sheds[0]} admission sheds (0 corrupted), budget stop at step "
        f"{res.samples.shape[1]}"
    )
    return doc


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + loose floors for CI")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the benchmark telemetry document")
    args = ap.parse_args()
    doc = main(smoke=args.smoke)
    if args.json:
        # write BEFORE the gate checks: on failure the artifact is the
        # investigation's starting point
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    thr, pri = doc["throughput"], doc["priority"]
    if thr["ratio"] < thr["min_ratio"]:
        raise SystemExit(
            f"concurrent throughput ratio {thr['ratio']} below the floor "
            f"{thr['min_ratio']}: fair-share scheduling is taxing throughput"
        )
    if pri["p99_ratio"] > pri["max_p99_ratio"]:
        raise SystemExit(
            f"high-priority p99 blew up {pri['p99_ratio']}x under overload "
            f"(cap {pri['max_p99_ratio']}x): tier precedence is not holding"
        )


if __name__ == "__main__":
    _cli()
