"""Race-detector stress smoke: perturbed-schedule fabric stress + the
instrumented-lock overhead budget.

Runs `repro.analysis.stress.run_stress` (exactly-once tap delivery,
router steal under concurrent waves, pool shutdown races — all under an
activated `LockMonitor` with schedule perturbation) and then measures
what the instrumentation itself costs on the lockstep evaluate_batch
path: the same single-driver wave workload timed against a plain fabric
and against one whose locks were built inside `monitored(...)` (with
perturbation DISABLED, so the number is pure bookkeeping overhead, not
injected jitter). The design target is < 5% on the lockstep path; the
smoke asserts a loose 25% bar because shared CI machines are noisy, and
records both numbers in the artifact.

    PYTHONPATH=src python -m benchmarks.race_stress [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.analysis.races import LockMonitor, monitored
from repro.analysis.stress import run_stress
from repro.core.fabric import CallableBackend, EvaluationFabric

#: design target for instrumentation overhead on the lockstep path
OVERHEAD_TARGET = 0.05
#: what the smoke actually asserts (CI machines are noisy)
OVERHEAD_SMOKE_BAR = 0.25


def _square(thetas):
    return (np.asarray(thetas) ** 2).sum(axis=1, keepdims=True)


def _lockstep_workload(fabric: EvaluationFabric, n_waves: int, n_points: int) -> float:
    """One lockstep driver issuing full waves — the ensemble-MCMC traffic
    shape — against `fabric`; returns wall seconds."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((n_points, 2))
    t0 = time.perf_counter()
    for _ in range(n_waves):
        fabric.evaluate_batch(X + rng.standard_normal((n_points, 2)))
    return time.perf_counter() - t0


def _measure_overhead(n_waves: int, n_points: int, repeats: int = 3) -> dict:
    """Best-of-`repeats`, alternating plain/instrumented so drift in the
    machine's load hits both variants equally."""
    plain_s = []
    mon_s = []
    for _ in range(repeats):
        fab = EvaluationFabric(CallableBackend(_square), cache_size=0)
        try:
            plain_s.append(_lockstep_workload(fab, n_waves, n_points))
        finally:
            fab.shutdown()
        monitor = LockMonitor(perturb=False)
        with monitored(monitor):
            fab = EvaluationFabric(CallableBackend(_square), cache_size=0)
        try:
            mon_s.append(_lockstep_workload(fab, n_waves, n_points))
        finally:
            fab.shutdown()
    best_plain, best_mon = min(plain_s), min(mon_s)
    return {
        "n_waves": n_waves,
        "n_points": n_points,
        "plain_s": round(best_plain, 4),
        "monitored_s": round(best_mon, 4),
        "overhead_frac": round((best_mon - best_plain) / best_plain, 4),
        "target_frac": OVERHEAD_TARGET,
        "smoke_bar_frac": OVERHEAD_SMOKE_BAR,
    }


def main(smoke: bool = True, threads: int = 8, seed: int = 0) -> dict:
    stress = run_stress(n_threads=threads, seed=seed, perturb=True)
    n_waves, n_points = (60, 64) if smoke else (300, 64)
    overhead = _measure_overhead(n_waves, n_points)
    doc = {
        "schema": "race-stress-v1",
        "created_unix": time.time(),
        "smoke": smoke,
        "stress": stress,
        "overhead": overhead,
    }
    mon = stress["monitor"]
    print(
        f"race stress: {'passed' if stress['passed'] else 'FAILED'} "
        f"({threads} threads, {mon['acquisitions']} acquisitions over "
        f"{len(mon['locks'])} locks, {len(mon['lock_order_cycles'])} "
        f"cycle(s), {len(mon['unguarded_writes'])} unguarded write(s)); "
        f"instrumentation overhead {overhead['overhead_frac']:+.1%} "
        f"(target < {OVERHEAD_TARGET:.0%})"
    )
    return doc


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer overhead waves)")
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the stress + overhead telemetry document")
    args = ap.parse_args()
    doc = main(smoke=args.smoke, threads=args.threads, seed=args.seed)
    if args.json:
        # write BEFORE the asserts: a failing smoke's artifact is exactly
        # what the investigation needs
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    if not doc["stress"]["passed"]:
        bad = {
            name: s["violations"]
            for name, s in doc["stress"]["scenarios"].items()
            if not s["passed"]
        }
        raise SystemExit(
            "race stress FAILED: "
            + (json.dumps(bad) if bad else "lock-order cycles or unguarded "
               f"writes: {json.dumps(doc['stress']['monitor'])}")
        )
    if doc["overhead"]["overhead_frac"] > OVERHEAD_SMOKE_BAR:
        raise SystemExit(
            f"instrumented-lock overhead {doc['overhead']['overhead_frac']:.1%} "
            f"exceeds even the loose smoke bar {OVERHEAD_SMOKE_BAR:.0%} "
            f"(design target {OVERHEAD_TARGET:.0%})"
        )


if __name__ == "__main__":
    _cli()
