"""Router failover smoke: kill one of two threaded backends MID-benchmark
and assert the run completes — the paper's k8s-restart story, minus k8s.

Two single-tenant `ThreadedPool` backends serve waves through a
`FabricRouter`. Halfway through, one pool is shut down abruptly (its
in-flight requests fail, later submits raise). The router must back the
dead backend off, steal its shards onto the survivor, and finish every
wave with correct results. Telemetry (steals, failures, per-backend share)
is written as JSON for the CI artifact.

    PYTHONPATH=src python -m benchmarks.router_failover [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np

from repro.core.fabric import EvaluationFabric, FabricRouter, ThreadedBackend
from repro.core.interface import Model
from repro.core.pool import ThreadedPool


class _SleepSquare(Model):
    def __init__(self, cost_s: float):
        super().__init__("forward")
        self.cost_s = cost_s

    def get_input_sizes(self, c=None):
        return [2]

    def get_output_sizes(self, c=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, p, c=None):
        time.sleep(self.cost_s)
        return [[float(np.sum(np.square(p[0])))]]


def main(
    quick: bool = True,
    n_waves: int = 8,
    n_points: int = 16,
    eval_cost_s: float = 0.01,
    kill_after_s: float | None = None,
) -> dict:
    pools = [
        ThreadedPool([_SleepSquare(eval_cost_s) for _ in range(2)]),
        ThreadedPool([_SleepSquare(eval_cost_s) for _ in range(2)]),
    ]
    router = FabricRouter(
        [ThreadedBackend(p) for p in pools], backoff_s=0.05
    )
    fabric = EvaluationFabric(router, cache_size=0)
    # one full wave takes ~ n_points/4 * cost; kill backend 1 mid-run
    kill_after_s = kill_after_s or (n_waves / 2) * (n_points / 4) * eval_cost_s
    killer = threading.Timer(kill_after_s, pools[1].shutdown)
    killer.daemon = True
    killer.start()

    rng = np.random.default_rng(0)
    completed = 0
    t0 = time.monotonic()
    for w in range(n_waves):
        X = rng.standard_normal((n_points, 2))
        out = fabric.evaluate_batch(X)
        np.testing.assert_allclose(
            out.ravel(), (X**2).sum(1), rtol=1e-6, atol=1e-9
        )
        completed += 1
    wall = time.monotonic() - t0
    killer.cancel()
    tel = fabric.telemetry()
    back = tel["backend"]
    fabric.shutdown()

    assert completed == n_waves, f"only {completed}/{n_waves} waves completed"
    doc = {
        "schema": "router-failover-v1",
        "created_unix": time.time(),
        "waves_completed": completed,
        "wall_s": round(wall, 3),
        "kill_after_s": round(kill_after_s, 3),
        "steals": back["steals"],
        "per_backend": [
            {
                "share": b["share"],
                "failures": b["failures"],
                "backoff_remaining_s": b["backoff_remaining_s"],
            }
            for b in back["per_backend"]
        ],
    }
    survived_share = doc["per_backend"][0]["share"]
    print(f"failover smoke: {completed}/{n_waves} waves completed with "
          f"backend 1 killed at t={kill_after_s:.2f}s "
          f"({doc['steals']} steals, survivor share {survived_share:.0%})")
    return doc


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the failover telemetry document")
    args = ap.parse_args()
    doc = main()
    if args.json:
        # write BEFORE the exercised-a-failure check: when the smoke fails,
        # the telemetry artifact is exactly what the investigation needs
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    if doc["steals"] < 1 and all(
        b["failures"] == 0 for b in doc["per_backend"]
    ):
        raise SystemExit(
            "failover smoke did not exercise a failure: the kill landed "
            "after the last wave — lower kill_after_s"
        )


if __name__ == "__main__":
    _cli()
