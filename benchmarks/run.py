"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a ``name,us_per_call,derived`` CSV line per benchmark (quick mode by
default so the suite completes in a few minutes on one CPU core; --full runs
the paper-scale protocols).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _csv(name: str, us_per_call: float, derived: str):
    print(f"CSV,{name},{us_per_call:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    quick = not args.full
    results = {}

    benches = []
    from benchmarks import mlda_tsunami, qmc_defects, roofline, sparse_grid_l2sea, weak_scaling

    benches = [
        ("weak_scaling_fig5", weak_scaling.main),
        ("sparse_grid_l2sea_sec4.1", sparse_grid_l2sea.main),
        ("qmc_defects_sec4.2", qmc_defects.main),
        ("mlda_tsunami_sec4.3", mlda_tsunami.main),
        ("roofline", roofline.main),
    ]
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.monotonic()
        try:
            out = fn(quick=quick)
            dt = time.monotonic() - t0
            derived = ""
            if name.startswith("weak_scaling") and out:
                rows = out["weak_scaling"] if isinstance(out, dict) else out
                derived = f"min_efficiency={min(r['efficiency'] for r in rows):.3f}"
                if isinstance(out, dict) and "http_round_trips" in out:
                    rt = out["http_round_trips"]["round_trip_reduction"]
                    derived += f";http_rt_reduction={rt:.1f}x"
            elif name.startswith("sparse_grid") and out:
                derived = f"speedup={out['speedup']:.1f};evals={out['total_evals']}"
            elif name.startswith("qmc") and out:
                derived = f"online_speedup={out['online_speedup']:.1f};relerr={out['rom_max_relerr']:.1e}"
            elif name.startswith("mlda") and out:
                derived = f"speedup={out['speedup']:.1f};evals={out['evals_per_level']}"
            elif name == "roofline" and out:
                fracs = [c["roofline_fraction"] for c in out]
                derived = f"cells={len(out)};median_frac={sorted(fracs)[len(fracs)//2]:.3f}"
            results[name] = out
            _csv(name, dt * 1e6, derived)
        except Exception as e:  # noqa: BLE001
            _csv(name, -1, f"FAILED:{e!r}")
            raise

    out_file = Path("experiments") / "bench_results.json"
    out_file.parent.mkdir(exist_ok=True)

    def _default(o):
        try:
            return float(o)
        except Exception:  # noqa: BLE001
            return str(o)

    out_file.write_text(json.dumps(results, indent=1, default=_default))
    print(f"\nresults -> {out_file}")


if __name__ == "__main__":
    main()
