"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json PATH]

Prints a ``name,us_per_call,derived`` CSV line per benchmark (quick mode by
default so the suite completes in a few minutes on one CPU core; --full runs
the paper-scale protocols). Machine-readable results — one row per benchmark
with ``name`` / ``us_per_call`` / ``evals_per_sec`` / ``derived`` plus the
full result payloads — always go to the ONE canonical ``BENCH_results.json``
at the repo root (override the path with ``--json``), so the perf trajectory
is tracked across PRs from a single file.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _csv(name: str, us_per_call: float, derived: str):
    print(f"CSV,{name},{us_per_call:.1f},{derived}")


def _fmt_imbalance(router: dict) -> str:
    # router_imbalance is None when no measured wave split across backends
    # (e.g. one backend sat in failure backoff for the whole window)
    def f(v):
        return f"{v:.2f}" if v is not None else "n/a"

    return (f";router_imbalance={f(router['latency']['imbalance'])}"
            f"(rr={f(router['round_robin']['imbalance'])})")


def _derived_and_rate(name: str, out) -> tuple[str, float | None]:
    """(derived summary string, evals/sec if the benchmark reports one)."""
    derived, rate = "", None
    if not out:
        return derived, rate
    if name.startswith("weak_scaling"):
        rows = out["weak_scaling"] if isinstance(out, dict) else out
        derived = f"min_efficiency={min(r['efficiency'] for r in rows):.3f}"
        if isinstance(out, dict) and "http_round_trips" in out:
            rt = out["http_round_trips"]["round_trip_reduction"]
            derived += f";http_rt_reduction={rt:.1f}x"
        if isinstance(out, dict) and "lockstep" in out:
            ls = out["lockstep"]
            derived += f";lockstep_speedup={ls['speedup']:.1f}x"
            rate = ls["ensemble_evals_per_sec"]
        if isinstance(out, dict) and "router" in out:
            derived += _fmt_imbalance(out["router"])
    elif name.startswith("batch_eval"):
        ts = out["tsunami_coarse"]
        derived = (f"tsunami_batch_speedup={ts['speedup']:.1f}x;"
                   f"fallback_points={out['fabric']['fallback_points']}")
        rate = ts["batch_evals_per_sec"]
    elif name.startswith("sparse_grid"):
        derived = f"speedup={out['speedup']:.1f};evals={out['total_evals']}"
    elif name.startswith("qmc"):
        derived = f"online_speedup={out['online_speedup']:.1f};relerr={out['rom_max_relerr']:.1e}"
    elif name.startswith("grad_mcmc"):
        derived = (f"mala_ess_per_wave={out['mala']['ess_per_wave']:.2f};"
                   f"rwm_ess_per_wave={out['rwm']['ess_per_wave']:.2f};"
                   f"ratio={out['ess_per_wave_ratio']:.2f}x")
        rate = out["mala"]["evals_per_sec"]
    elif name.startswith("surrogate_da"):
        surr = out["surrogate_three_stage"]
        derived = (
            f"coarse_evals_per_ess_reduction="
            f"{out['coarse_evals_per_ess_reduction']:.1f}x;"
            f"screen_pass_rate={surr['screen']['pass_rate']}"
        )
        rate = surr["coarse_evals_per_sec"]
    elif name.startswith("mlda"):
        derived = f"speedup={out['speedup']:.1f};evals={out['evals_per_level']}"
        if isinstance(out, dict) and "ensemble" in out:
            derived += f";ensemble_speedup={out['ensemble']['speedup']:.1f}x"
            rate = out["ensemble"]["ensemble_evals_per_sec"]
        if isinstance(out, dict) and "ensemble_mlda" in out:
            em = out["ensemble_mlda"]
            derived += f";ensemble_mlda_speedup={em['speedup']:.1f}x"
            rate = em["ensemble_evals_per_sec"]
        if isinstance(out, dict) and "router" in out:
            derived += _fmt_imbalance(out["router"])
    elif name.startswith("fused_sampler"):
        g, ts = out["gaussian"], out["tsunami_coarse"]
        derived = (
            f"fused_speedup_gaussian={g['speedup_vs_host_fabric']:.1f}x;"
            f"fused_speedup_tsunami={ts['speedup_vs_host_fabric']:.1f}x;"
            f"stencil_parity_err={out['swe_stencil']['max_abs_err_vs_jitted_ref']:.1e}"
        )
        rate = ts["fused_steps_per_sec"] * out["chains"]
    elif name.startswith("multi_tenant"):
        thr, pri = out["throughput"], out["priority"]
        derived = (
            f"throughput_ratio={thr['ratio']:.2f};"
            f"hi_p99_ratio={pri['p99_ratio']:.2f};"
            f"shared_hits={out['cache']['shared_hits_taken']};"
            f"sheds={out['admission']['sheds']};"
            f"corrupted={out['admission']['corrupted']}"
        )
        rate = thr["concurrent_evals_per_sec"]
    elif name.startswith("elastic_fleet"):
        ch, ck = out["chaos"], out["checkpoint"]
        derived = (
            f"chaos_throughput_ratio={ch['throughput_ratio']:.2f};"
            f"spec_dispatches={ch['spec_dispatches']};"
            f"resume_exact={ck['resume_exact']};"
            f"wave_savings={ck['wave_savings']:.2f}"
        )
        rate = ch["evals_per_sec"]
    elif name.startswith("second_order"):
        ml, rt, lp = out["mlda"], out["router"], out["laplace"]
        derived = (
            f"mala_ess_ratio={ml['ratio']:.2f}x;"
            f"laplace_full_wall_s={lp['full']['wall_s']:.1f};"
            f"imbalance_per_cap={rt['per_capability']:.2f}"
            f"(blended={rt['blended']:.2f})"
        )
        rate = ml["fine_evals_per_sec"]
    elif name == "roofline":
        fracs = [c["roofline_fraction"] for c in out]
        derived = f"cells={len(out)};median_frac={sorted(fracs)[len(fracs)//2]:.3f}"
    return derived, rate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="BENCH_results.json", metavar="PATH",
                    help="machine-readable results path (default: the "
                         "canonical BENCH_results.json at the repo root)")
    args, _ = ap.parse_known_args()
    quick = not args.full
    results = {}
    rows = []

    from benchmarks import (
        batch_eval,
        elastic_fleet,
        fused_sampler,
        grad_mcmc,
        mlda_tsunami,
        multi_tenant,
        qmc_defects,
        roofline,
        second_order,
        sparse_grid_l2sea,
        surrogate_da,
        weak_scaling,
    )

    benches = [
        ("batch_eval", batch_eval.main),
        ("weak_scaling_fig5", weak_scaling.main),
        ("sparse_grid_l2sea_sec4.1", sparse_grid_l2sea.main),
        ("qmc_defects_sec4.2", qmc_defects.main),
        ("mlda_tsunami_sec4.3", mlda_tsunami.main),
        ("grad_mcmc_mala", grad_mcmc.main),
        ("fused_sampler", fused_sampler.main),
        ("surrogate_da_sec4.3", surrogate_da.main),
        ("second_order", second_order.main),
        ("elastic_fleet", elastic_fleet.main),
        ("multi_tenant", multi_tenant.main),
        ("roofline", roofline.main),
    ]
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.monotonic()
        try:
            out = fn(quick=quick)
            dt = time.monotonic() - t0
            derived, rate = _derived_and_rate(name, out)
            results[name] = out
            rows.append(
                {
                    "name": name,
                    "us_per_call": round(dt * 1e6, 1),
                    "evals_per_sec": rate,
                    "derived": derived,
                }
            )
            _csv(name, dt * 1e6, derived)
        except Exception as e:  # noqa: BLE001
            _csv(name, -1, f"FAILED:{e!r}")
            if args.json:
                _write_json(args.json, quick, rows, results, failed=f"{name}: {e!r}")
            raise

    # ONE canonical results file (the old scratch copy under experiments/
    # is gone — experiments/ stays gitignored for ad-hoc local output)
    _write_json(args.json, quick, rows, results)
    print(f"\nresults -> {args.json}")


def _jsonable(o):
    try:
        return float(o)
    except Exception:  # noqa: BLE001
        return str(o)


def _write_json(path: str, quick: bool, rows: list, results: dict, failed: str | None = None):
    doc = {
        "schema": "bench-v1",
        "created_unix": time.time(),
        "mode": "quick" if quick else "full",
        "benchmarks": rows,
        "results": results,
    }
    if failed:
        doc["failed"] = failed
    Path(path).write_text(json.dumps(doc, indent=1, default=_jsonable))


if __name__ == "__main__":
    main()
