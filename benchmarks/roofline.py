"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and reports, per (arch x shape x mesh):
  compute_s   = HLO_FLOPs / (peak bf16 FLOP/s)          [per device]
  memory_s    = HLO bytes accessed / HBM bandwidth       [per device]
  collective_s= ring-model link bytes / ICI link bandwidth [per device]
  dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute fraction), and the
  roofline fraction = useful-compute time / dominant-term time.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.types import V5E

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(directory: Path | None = None) -> list[dict]:
    d = directory or DRYRUN_DIR
    cells = []
    for f in sorted(d.glob("*.json")):
        data = json.loads(f.read_text())
        t = data["roofline_terms_s"]
        useful_s = data["model_flops_per_device"] / V5E.peak_flops_bf16
        bound = max(t.values())
        data["useful_s"] = useful_s
        data["bound_s"] = bound
        if data.get("kind") == "decode" and data.get("memory_ideal_s"):
            # single-token decode is memory-bound by physics: measure against
            # the must-move-bytes floor (params + cache r/w per step)
            data["roofline_fraction"] = data["memory_ideal_s"] / t["memory_s"]
        else:
            data["roofline_fraction"] = useful_s / bound if bound else 0.0
        cells.append(data)
    return cells


def format_table(cells: list[dict], mesh: str | None = None) -> str:
    rows = [c for c in cells if mesh is None or c["mesh"].count("x") == (2 if mesh == "multi" else 1)]
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful/HLO | roofline_frac |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for c in sorted(rows, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        t = c["roofline_terms_s"]
        uf = c.get("useful_flops_fraction") or 0.0
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| {c['dominant'].replace('_s','')} | {uf:.2f} | {c['roofline_fraction']:.3f} |"
        )
    return hdr + "\n".join(lines)


def main(quick: bool = False):
    cells = load_cells()
    if not cells:
        print("no dry-run artifacts found; run: python -m repro.launch.dryrun")
        return []
    print(format_table(cells, mesh="single"))
    worst = sorted(cells, key=lambda c: c["roofline_fraction"])[:3]
    coll = sorted(cells, key=lambda c: -c["roofline_terms_s"]["collective_s"])[:3]
    print("\nworst roofline fractions:",
          [(c["arch"], c["shape"], c["mesh"], round(c["roofline_fraction"], 4)) for c in worst])
    print("most collective-bound:",
          [(c["arch"], c["shape"], c["mesh"]) for c in coll])
    return cells


if __name__ == "__main__":
    main()
