"""Batch-native model evaluation: sequential `__call__` vs `evaluate_batch`.

The tentpole measurement for the batched hot path: N thetas through (a) the
per-point path every UQ framework pays (one host round-trip per point, the
UQpy/QUEENS dispatch tax) and (b) ONE native `evaluate_batch` wave. Also
demonstrates the fabric's native-batch telemetry: waves dispatched to a
batch-capable model (`capabilities().evaluate_batch`) never shatter into
per-point fallback calls.
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.composite import CompositeModel
from repro.apps.tsunami import TsunamiModel
from repro.core.fabric import EvaluationFabric, ModelBackend


def _bench_model(model, thetas, config, n_seq: int | None = None) -> dict:
    """Time n sequential __call__s vs one evaluate_batch of the same points
    (both paths warmed first so jit compilation is excluded)."""
    thetas = np.atleast_2d(thetas)
    N = len(thetas)
    model([list(thetas[0])], config)
    model.evaluate_batch(thetas, config)

    n_seq = N if n_seq is None else n_seq  # subsample when __call__ is slow
    t0 = time.monotonic()
    seq = np.array([model([list(t)], config)[0] for t in thetas[:n_seq]])
    t_seq = (time.monotonic() - t0) * (N / n_seq)

    t_bat = 1e9
    for _ in range(2):
        t0 = time.monotonic()
        bat = model.evaluate_batch(thetas, config)
        t_bat = min(t_bat, time.monotonic() - t0)

    k = min(n_seq, len(bat))
    maxrel = float(np.max(np.abs(seq[:k] - bat[:k]) / (np.abs(seq[:k]) + 1e-9)))
    return {
        "n_points": N,
        "sequential_s": round(t_seq, 3),
        "batched_s": round(t_bat, 4),
        "speedup": round(t_seq / t_bat, 2),
        "seq_evals_per_sec": round(N / t_seq, 1),
        "batch_evals_per_sec": round(N / t_bat, 1),
        "max_rel_diff": maxrel,
    }


def run(n_points: int = 64, quick: bool = False) -> dict:
    rng = np.random.default_rng(7)
    out = {}

    # -- tsunami, coarse level (the acceptance measurement) ------------------
    tsunami = TsunamiModel()
    thetas = np.stack(
        [rng.uniform(30.0, 150.0, n_points), rng.uniform(0.5, 4.0, n_points)], axis=1
    )
    out["tsunami_coarse"] = _bench_model(tsunami, thetas, {"level": 0})
    r = out["tsunami_coarse"]
    print(f"tsunami coarse x{n_points}: sequential {r['sequential_s']}s "
          f"({r['seq_evals_per_sec']}/s) -> batched {r['batched_s']}s "
          f"({r['batch_evals_per_sec']}/s) = {r['speedup']}x, "
          f"max rel diff {r['max_rel_diff']:.1e}")

    if not quick:
        fine = thetas[:8]
        out["tsunami_fine"] = _bench_model(tsunami, fine, {"level": 1}, n_seq=4)
        r = out["tsunami_fine"]
        print(f"tsunami fine x8: {r['speedup']}x "
              f"({r['seq_evals_per_sec']}/s -> {r['batch_evals_per_sec']}/s)")

    # -- composite ROM online stage ------------------------------------------
    composite = CompositeModel()
    cth = np.stack(
        [rng.uniform(60.0, 95.0, 8), rng.uniform(150.0, 270.0, 8), rng.uniform(5.0, 40.0, 8)],
        axis=1,
    )
    out["composite_rom"] = _bench_model(composite, cth, {"mode": "rom"})
    r = out["composite_rom"]
    print(f"composite rom x8: {r['speedup']}x "
          f"({r['seq_evals_per_sec']}/s -> {r['batch_evals_per_sec']}/s)")

    # -- fabric native-batch telemetry ---------------------------------------
    with EvaluationFabric(ModelBackend(tsunami), cache_size=0) as fabric:
        fabric.evaluate_batch(thetas[: min(16, n_points)], {"level": 0})
        t = fabric.telemetry()
        back = t["backend"]
        out["fabric"] = {
            "native": back["native"],
            "native_batches": back["native_batches"],
            "native_points": back["native_points"],
            "fallback_points": back["fallback_points"],
            "padded": back["padded"],
            "wave_fill": round(t["wave_fill"], 3),
        }
    print(f"fabric: native_batches={out['fabric']['native_batches']} "
          f"fallback_points={out['fabric']['fallback_points']} "
          f"padded={out['fabric']['padded']} — whole waves hit the vmapped program")
    return out


def main(quick: bool = False) -> dict:
    # the acceptance measurement is 64 coarse thetas — keep it in quick mode
    # too (quick only drops the fine-level comparison)
    return run(n_points=64, quick=quick)


if __name__ == "__main__":
    main()
