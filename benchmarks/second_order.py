"""Second-order wire surface benchmark (ROADMAP item 5): what does the
`/ApplyHessianBatch` + per-capability-router slice buy on the tsunami
inverse problem?

Three phases:

1. **gradient-informed MLDA** — `ensemble_mlda(coarse_sampler="mala")`
   vs the blind random-walk baseline on the SAME coarsened tsunami
   posterior (sharp heights/arrival-time likelihood, data drawn at the
   fine level). The coarse MALA subchains ride fused value-and-gradient
   waves; delayed acceptance stays exact at the fine level. Headline
   number: ESS per fine-model evaluation, MALA / blind — the ISSUE's
   acceptance bar is >= 1.5x (`min_ratio`, quick/full modes).
2. **Laplace preview** — `laplace_preview` on tsunami level 0 with both
   curvature modes; "full" exercises the new `apply_hessian` waves
   (reverse-over-forward HVPs through the lax.scan solver), "gn" is the
   Jacobian-only control. Records wall time, wave counts, and the
   GN-vs-full MAP agreement.
3. **mixed-traffic router** — an evaluate+gradient storm over a
   4-backend pool whose adjoint costs span 4x (forward costs uniform).
   Per-(backend, capability) EWMAs must hold the wave-split imbalance
   <= `max_imbalance` (1.3); the pre-fix blended estimate is re-measured
   via ablation (`_ewma_for` pinned to the cross-op blend) and recorded
   alongside as the regression baseline.

    PYTHONPATH=src python -m benchmarks.second_order [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.apps.tsunami import TsunamiModel
from repro.core.fabric import EvaluationFabric, FabricRouter, ModelBackend
from repro.core.interface import Capabilities, Model
from repro.uq.inference import laplace_preview
from repro.uq.mcmc import effective_sample_size
from repro.uq.mlda import ensemble_mlda

TRUE_THETA = np.array([90.0, 2.5])
PRIOR = ((30.0, 150.0), (0.5, 4.0))
NOISE_SD = np.array([0.5, 0.05, 0.5, 0.05])


def _bench_model(smoke: bool) -> TsunamiModel:
    class Bench(TsunamiModel):
        # coarsened pair so quick mode finishes in ~1 min on one CPU core
        N_CELLS = {0: 64, 1: 128} if smoke else {0: 128, 1: 256}

    return Bench()


def _pooled_ess(samples: np.ndarray, burn: float = 0.2) -> float:
    """Mean over both parameters of the ESS summed across chains."""
    b = int(samples.shape[1] * burn)
    d = samples.shape[2]
    return float(sum(
        effective_sample_size(samples[k, b:, j])
        for k in range(len(samples)) for j in range(d)
    )) / d


# -- phase 1: gradient-informed vs blind MLDA ---------------------------------


def _mlda_phase(model, smoke: bool, quick: bool) -> dict:
    rng = np.random.default_rng(3)
    data = np.asarray(model([list(TRUE_THETA)], {"level": 1})[0])
    data = data + rng.standard_normal(4) * NOISE_SD

    def loglik(y):
        return -0.5 * float(np.sum(((np.asarray(y) - data) / NOISE_SD) ** 2))

    def grad_loglik(y):  # traceable: rides the fused value+grad wave
        return -(y - data) / NOISE_SD**2

    def logprior(th):
        ok = all(lo <= t <= hi for t, (lo, hi) in zip(th, PRIOR))
        return 0.0 if ok else -np.inf

    def grad_logprior(th):
        return np.zeros(2)

    n_chains = 8 if smoke else 16
    n_samples = 60 if smoke else (160 if quick else 240)
    x0s = TRUE_THETA + rng.standard_normal((n_chains, 2)) * [4.0, 0.15]
    configs = [{"level": 0}, {"level": 1}]

    def run(prop_cov, **kw) -> dict:
        fab = EvaluationFabric(ModelBackend(model), cache_size=4096)
        t0 = time.monotonic()
        try:
            res = ensemble_mlda(
                None, x0s.copy(), n_samples, [4], prop_cov,
                np.random.default_rng(42), fabric=fab, loglik=loglik,
                logprior=logprior, level_configs=configs, **kw,
            )
        finally:
            fab.shutdown()
        wall = time.monotonic() - t0
        fine = res.evals_per_level[-1]
        ess = _pooled_ess(res.samples)
        return {
            "ess": round(ess, 2),
            "fine_evals": int(fine),
            "ess_per_fine_eval": ess / fine,
            "accept_rates": [round(a, 3) for a in res.accept_rates],
            "wall_s": round(wall, 2),
        }

    # blind baseline: proposal tuned to the POSTERIOR scale (fair fight)
    blind = run(np.diag([8.0**2, 0.25**2]))
    # MALA coarse subchains: preconditioner ~ posterior covariance
    mala = run(
        np.diag([4.0, 0.01]), coarse_sampler="mala", mala_step=1.0,
        grad_loglik=grad_loglik, grad_logprior=grad_logprior,
    )
    ratio = mala["ess_per_fine_eval"] / blind["ess_per_fine_eval"]
    return {
        "blind": blind,
        "mala": mala,
        "ratio": round(ratio, 3),
        # smoke sizes are too small for a stable ESS estimate: sanity
        # floor only; quick/full assert the ISSUE's acceptance bar
        "min_ratio": 0.2 if smoke else 1.5,
        "fine_evals_per_sec": round(mala["fine_evals"] / mala["wall_s"], 1),
    }


# -- phase 2: Laplace preview wall time ---------------------------------------


def _laplace_phase(model, smoke: bool) -> dict:
    rng = np.random.default_rng(3)
    data = np.asarray(model([list(TRUE_THETA)], {"level": 1})[0])
    data = data + rng.standard_normal(4) * NOISE_SD
    out = {}
    for curvature in ("gn", "full"):
        with EvaluationFabric(ModelBackend(model), cache_size=0) as fab:
            t0 = time.monotonic()
            res = laplace_preview(
                fab, data, np.diag(NOISE_SD**2), TRUE_THETA + [5.0, -0.3],
                np.diag([100.0, 0.25]), curvature=curvature, n_ensemble=4,
                n_iters=4 if smoke else 8, rng=np.random.default_rng(0),
                config={"level": 0},
            )
            wall = time.monotonic() - t0
            pc = fab.telemetry()["per_capability"]
        out[curvature] = {
            "wall_s": round(wall, 2),
            "map": [round(float(v), 3) for v in res.mean],
            "posterior_sd": [
                round(float(v), 4) for v in np.sqrt(np.diag(res.cov))
            ],
            "hessian_waves": pc.get("apply_hessian", {}).get("waves", 0),
            "value_grad_waves": pc["value_and_gradient"]["waves"],
        }
    out["map_agreement"] = round(float(np.max(np.abs(
        np.asarray(out["full"]["map"]) - np.asarray(out["gn"]["map"])
    ))), 4)
    return out


# -- phase 3: mixed-traffic router imbalance ----------------------------------


class _TimedOpModel(Model):
    """Quadratic with separately tunable forward/adjoint per-point costs."""

    def __init__(self, eval_cost_s: float, grad_cost_s: float):
        super().__init__("forward")
        self.eval_cost_s = eval_cost_s
        self.grad_cost_s = grad_cost_s

    def get_input_sizes(self, c=None):
        return [2]

    def get_output_sizes(self, c=None):
        return [1]

    def capabilities(self, config=None):
        return Capabilities(
            evaluate=True, evaluate_batch=True, gradient=True,
            gradient_batch=True,
        )

    def __call__(self, theta, config=None):
        return self.evaluate_batch([theta], config)[0]

    def evaluate_batch(self, thetas, config=None):
        thetas = np.atleast_2d(thetas)
        time.sleep(self.eval_cost_s * len(thetas))
        return (thetas**2).sum(1, keepdims=True)

    def gradient_batch(self, thetas, senss, config=None):
        thetas = np.atleast_2d(thetas)
        time.sleep(self.grad_cost_s * len(thetas))
        return 2 * thetas * np.atleast_2d(senss)


def _router_phase(smoke: bool) -> dict:
    # forward solvers uniform, adjoints span 4x across the pool
    costs = [(0.0008, 0.0008), (0.0008, 0.0008),
             (0.0008, 0.0032), (0.0008, 0.0032)]
    n_rounds = 4 if smoke else 8
    n_points = 32 if smoke else 48

    def storm(router) -> tuple[float, float]:
        rng = np.random.default_rng(1)
        fab = EvaluationFabric(router, cache_size=0)
        try:
            for _ in range(2):  # warm BOTH per-op estimates
                fab.evaluate_batch(rng.standard_normal((n_points, 2)))
                fab.gradient_batch(
                    rng.standard_normal((n_points, 2)),
                    np.ones((n_points, 1)),
                )
            router.reset_stats()
            t0 = time.monotonic()
            for _ in range(n_rounds):
                X = rng.standard_normal((n_points, 2))
                fab.evaluate_batch(X)
                fab.gradient_batch(X, np.ones((n_points, 1)))
            wall = time.monotonic() - t0
            return router.stats()["imbalance_ewma"], wall
        finally:
            fab.shutdown()

    def mk_router() -> FabricRouter:
        return FabricRouter([ModelBackend(_TimedOpModel(*c)) for c in costs])

    per_cap, wall_p = storm(mk_router())
    blended_router = mk_router()
    # ablate the fix: route every op on the blended cross-op estimate
    blended_router._ewma_for = (
        lambda i, op: blended_router._ewma_s[i]
    )
    blended, wall_b = storm(blended_router)
    return {
        "per_capability": round(per_cap, 3),
        "blended": round(blended, 3),
        "wall_per_capability_s": round(wall_p, 2),
        "wall_blended_s": round(wall_b, 2),
        # loaded CI runners jitter the sleeps: looser smoke ceiling
        "max_imbalance": 1.6 if smoke else 1.3,
    }


def main(quick: bool = True, smoke: bool = False) -> dict:
    model = _bench_model(smoke)
    mlda = _mlda_phase(model, smoke, quick)
    print(f"  mlda: mala {mlda['mala']['ess_per_fine_eval']:.4f} vs blind "
          f"{mlda['blind']['ess_per_fine_eval']:.4f} ESS/fine-eval "
          f"-> {mlda['ratio']:.2f}x (floor {mlda['min_ratio']}x)")
    laplace = _laplace_phase(model, smoke)
    print(f"  laplace: gn {laplace['gn']['wall_s']}s / full "
          f"{laplace['full']['wall_s']}s "
          f"({laplace['full']['hessian_waves']} hessian waves), "
          f"MAP agreement {laplace['map_agreement']}")
    router = _router_phase(smoke)
    print(f"  router: imbalance {router['per_capability']} per-capability "
          f"vs {router['blended']} blended "
          f"(ceiling {router['max_imbalance']})")
    return {"mlda": mlda, "laplace": laplace, "router": router}


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + loose floors for CI")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the benchmark telemetry document")
    args = ap.parse_args()
    doc = main(smoke=args.smoke)
    if args.json:
        # write BEFORE the gate checks: on failure the artifact is the
        # investigation's starting point
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    ml, rt = doc["mlda"], doc["router"]
    if ml["ratio"] < ml["min_ratio"]:
        raise SystemExit(
            f"gradient-informed MLDA ESS/fine-eval ratio {ml['ratio']} below "
            f"the floor {ml['min_ratio']}: MALA coarse subchains are not "
            f"paying for their gradient waves"
        )
    if rt["per_capability"] > rt["max_imbalance"]:
        raise SystemExit(
            f"mixed-traffic imbalance {rt['per_capability']} above the "
            f"ceiling {rt['max_imbalance']}: per-capability EWMAs are not "
            f"holding the split"
        )
    if doc["laplace"]["full"]["hessian_waves"] == 0:
        raise SystemExit(
            "laplace curvature='full' dispatched no apply_hessian waves: "
            "the second-order path is not reaching the fabric"
        )


if __name__ == "__main__":
    _cli()
