"""Fused sampler blocks: steps/s per-step vs device-resident (`uq.fused`).

Three measurements, one per claim:

1. **Gaussian posterior** — the target costs a handful of FLOPs, so steps/s
   is a pure measurement of sampler-loop dispatch economics. Two
   comparators for the fused block:
   * ``per_step`` — the SAME compiled scan program with S=1, dispatched
     once per step with a host round trip (apples-to-apples dispatch cost;
     bit-identical trajectories).
   * ``host+fabric`` — `ensemble_random_walk_metropolis`'s host loop with a
     `batched_logpost` over an `EvaluationFabric`, i.e. the pre-fused
     campaign path every sampler in this repo used.
2. **Coarse tsunami posterior** — `apps.tsunami._solve_batch` at a reduced
   resolution chosen so the solve costs ~tens of µs and the run is
   DISPATCH-bound (at the paper's 512-cell coarse level the solve itself
   dominates and no loop restructuring can win 10x; the fused win there is
   the removed per-step latency floor, not wall-clock compute).
3. **SWE stencil microbench** — one `kernels.swe` Rusanov step: jitted
   inline scan math vs the Pallas kernel (interpret mode on CPU — an
   emulation, so its µs/step is a correctness artifact, not TPU perf) plus
   the parity error against the jitted reference.

    PYTHONPATH=src python -m benchmarks.fused_sampler [--smoke] [--json PATH]

The two-size timing (`_net_rate`) subtracts the per-call fixed cost (init
log-density wave, host bookkeeping; the scan block itself is compiled once
and memoized in `uq.fused._BLOCK_MEMO`) — steady-state steps/s is the
honest number, matching how a campaign amortizes one large ``n_steps``.
"""
from __future__ import annotations

import argparse
import json
import time
from functools import partial
from pathlib import Path

import numpy as np


def _net_rate(run, n_big: int, n_small: int) -> float:
    """Steps/s with fixed per-call cost (compile, init wave) subtracted:
    run(n) twice at two sizes, rate = (n_big - n_small) / (t_big - t_small).
    The first small run populates persistent caches (XLA, bathymetry)."""
    run(n_small)
    t0 = time.perf_counter()
    run(n_small)
    t_small = time.perf_counter() - t0
    t0 = time.perf_counter()
    run(n_big)
    t_big = time.perf_counter() - t0
    return float((n_big - n_small) / max(t_big - t_small, 1e-9))


def _bench_posterior(logpost_dev, loglik_host, x0s, prop_cov, *,
                     fused_steps: int, n_big: int, n_host: int) -> dict:
    """Fused vs per-step vs host+fabric steps/s on one traceable posterior."""
    import jax
    import jax.numpy as jnp

    from repro.core.fabric import EvaluationFabric
    from repro.uq.fused import fused_ensemble_rwm
    from repro.uq.mcmc import batched_logpost, ensemble_random_walk_metropolis

    key = jax.random.key(0)
    S = fused_steps

    fused = _net_rate(
        lambda n: fused_ensemble_rwm(logpost_dev, x0s, n, prop_cov, key,
                                     fused_steps=S),
        n_big, S)
    per_step = _net_rate(
        lambda n: fused_ensemble_rwm(logpost_dev, x0s, n, prop_cov, key,
                                     fused_steps=S, per_step=True),
        n_host, max(n_host // 10, 1))

    # pre-fused campaign path: host lockstep loop, one fabric wave per step
    lp_jit = jax.jit(logpost_dev)

    def model_batch(thetas, cfg=None):
        return np.atleast_2d(np.asarray(
            lp_jit(jnp.asarray(np.atleast_2d(thetas), jnp.float32)))).T

    fabric = EvaluationFabric(model_batch)
    try:
        lp_host = batched_logpost(fabric, loglik_host)
        host = _net_rate(
            lambda n: ensemble_random_walk_metropolis(
                lp_host, x0s, n, prop_cov, np.random.default_rng(0)),
            n_host, max(n_host // 10, 1))
    finally:
        fabric.shutdown()

    return {
        "fused_steps": S,
        "fused_steps_per_sec": fused,
        "per_step_steps_per_sec": per_step,
        "host_fabric_steps_per_sec": host,
        "speedup_vs_per_step": fused / per_step,
        "speedup_vs_host_fabric": fused / host,
    }


def _bench_swe_stencil(reps: int) -> dict:
    """One Rusanov step on a [512, 64] tile: jitted scan math vs kernel."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.swe.ops import swe_step
    from repro.kernels.swe.ref import swe_step_ref

    C, N = 512, 64
    rng = np.random.default_rng(0)
    x = np.linspace(0.0, 1.0, C)[:, None]
    b = jnp.asarray(0.1 * np.sin(3 * np.pi * x))
    h = jnp.asarray(0.7 + 0.2 * rng.random((C, N)))
    hu = jnp.asarray(0.05 * rng.standard_normal((C, N)))

    jref = jax.jit(lambda a, q, bb: swe_step_ref(a, q, bb, 0.02))
    kern = partial(swe_step, dt_dx=0.02, impl="interpret")

    def rate(fn):
        jax.block_until_ready(fn(h, hu, b))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(h, hu, b)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    us_ref = rate(jref)
    us_kernel = rate(kern)
    rh, rhu = jref(h, hu, b)
    kh, khu = kern(h, hu, b)
    err = max(float(jnp.max(jnp.abs(kh - rh))), float(jnp.max(jnp.abs(khu - rhu))))
    return {
        "cells": C, "batch": N,
        "ref_us_per_step": us_ref,
        "kernel_interpret_us_per_step": us_kernel,
        "max_abs_err_vs_jitted_ref": err,
        # interpret mode emulates the TPU kernel op-by-op on CPU — its
        # timing is for the record, the parity number is the point here
        "note": "interpret-mode timing; pallas path targets TPU",
    }


def main(quick: bool = True, smoke: bool = False) -> dict:
    import jax.numpy as jnp

    from repro.apps.tsunami import _solve_batch
    from repro.uq.fused import gaussian_likelihood_target, gaussian_target

    if smoke:
        S, n_big, n_host, reps = 50, 500, 100, 3
    elif quick:
        S, n_big, n_host, reps = 200, 4000, 500, 10
    else:
        S, n_big, n_host, reps = 500, 20000, 2000, 30

    # -- 1: Gaussian (dispatch economics in isolation) ------------------------
    d, K = 4, 8
    mean = np.ones(d)
    lp_gauss = gaussian_target(mean)
    x0s = np.random.default_rng(0).normal(size=(K, d))

    def loglik_gauss(y):
        return float(np.ravel(y)[0])

    gauss = _bench_posterior(
        lp_gauss, loglik_gauss, x0s, (2.4**2 / d) * np.eye(d),
        fused_steps=S, n_big=n_big, n_host=n_host)

    # -- 2: coarse tsunami posterior (dispatch-bound reduced level) ------------
    n_cells = 8 if (smoke or quick) else 16
    fwd = partial(_solve_batch, n_cells=n_cells, smoothed=True)
    data = np.asarray(fwd(jnp.asarray([[100.0, 1.0]], jnp.float32)))[0]
    lp_tsu = gaussian_likelihood_target(
        fwd, data, 0.2, prior_bounds=[(60.0, 140.0), (0.5, 1.5)])
    x0t = np.random.default_rng(1).uniform([80, 0.8], [120, 1.2], (K, 2))

    def loglik_tsu(y):
        return float(np.ravel(y)[0])

    tsunami = _bench_posterior(
        lp_tsu, loglik_tsu, x0t, np.diag([25.0, 0.01]),
        fused_steps=S, n_big=max(n_big // 2, S), n_host=n_host)
    tsunami["n_cells"] = n_cells

    # -- 3: SWE stencil microbench ---------------------------------------------
    stencil = _bench_swe_stencil(reps)

    doc = {
        "schema": "repro-fused-sampler-v1",
        "mode": "smoke" if smoke else ("quick" if quick else "full"),
        "chains": K,
        "gaussian": gauss,
        "tsunami_coarse": tsunami,
        "swe_stencil": stencil,
    }
    print(
        f"fused sampler: gaussian {gauss['fused_steps_per_sec']:.0f} steps/s "
        f"({gauss['speedup_vs_per_step']:.1f}x vs per-step, "
        f"{gauss['speedup_vs_host_fabric']:.1f}x vs host+fabric); "
        f"tsunami[{n_cells} cells] {tsunami['fused_steps_per_sec']:.0f} steps/s "
        f"({tsunami['speedup_vs_per_step']:.1f}x vs per-step, "
        f"{tsunami['speedup_vs_host_fabric']:.1f}x vs host+fabric); "
        f"stencil parity err {stencil['max_abs_err_vs_jitted_ref']:.1e}"
    )
    return doc


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + loose speedup floor for CI")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the measurement document")
    args = ap.parse_args()
    doc = main(smoke=args.smoke)
    if args.json:
        # write BEFORE the gate checks: on failure the artifact is the
        # investigation's starting point
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"results -> {args.json}")
    # CI smoke gates: loose floors (loaded shared runners); the quick/full
    # numbers in BENCH_results.json carry the paper-level claim
    floor = 2.0 if doc["mode"] == "smoke" else 5.0
    for name in ("gaussian", "tsunami_coarse"):
        got = doc[name]["speedup_vs_host_fabric"]
        if got < floor:
            raise SystemExit(
                f"{name}: fused speedup {got:.1f}x below the {floor}x floor "
                f"— the fused block is not amortizing dispatch")
    if doc["swe_stencil"]["max_abs_err_vs_jitted_ref"] != 0.0:
        raise SystemExit(
            "swe stencil kernel drifted from the jitted reference "
            f"({doc['swe_stencil']['max_abs_err_vs_jitted_ref']:.3e})")


if __name__ == "__main__":
    _cli()
