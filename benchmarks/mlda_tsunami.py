"""Paper §4.3 / Figs. 8-10: MLDA tsunami source inversion.

3-level hierarchy, exactly the paper's construction:
  level 0: GP emulator (Matérn-5/2 ARD, type-II MLE) trained on
           low-discrepancy (Sobol') samples of the smoothed model,
  level 1: smoothed-bathymetry SWE at coarse resolution,
  level 2: fully-resolved SWE,
with subsampling rates (25, 2), Gaussian random-walk proposals pre-tuned on
the GP posterior, N independent chains x 7 fine samples each (paper: 100
chains, 2800 cores, speedup 96.38).
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.tsunami import TsunamiModel
from repro.core.fabric import EvaluationFabric, ModelBackend
from repro.core.interface import Model, model_capabilities
from repro.core.pool import ThreadedPool
from repro.uq.gp import GP
from repro.uq.mcmc import (
    batched_logpost,
    ensemble_random_walk_metropolis,
    gelman_rubin,
    random_walk_metropolis,
    run_chains,
)
from repro.uq.mlda import (
    batched_level_logposts,
    ensemble_mlda,
    fabric_logposts,
    mlda,
)
from repro.uq.qmc import sobol

TRUE_THETA = np.array([90.0, 2.5])
PRIOR = ((30.0, 150.0), (0.5, 4.0))  # x0 [km], amplitude [m]
NOISE_SD = np.array([0.5, 0.05, 0.5, 0.05])  # arrival [min], height [m]


class _RemoteModel(Model):
    """Adds a fixed dispatch latency per evaluation — emulates the paper's
    deployment where PDE levels live on a remote cluster. Sits BELOW the
    fabric, so cache hits genuinely skip the round-trip. A batched wave pays
    ONE latency (the cluster's instances run concurrently) and flows into
    the inner model's native `evaluate_batch`; per-point calls pay one
    latency EACH — exactly the dispatch tax the lockstep samplers remove.
    `native=False` disables the batch path (the 'before' configuration).
    `slowdown` emulates a uniformly slower sub-cluster (4x-slower hardware:
    solve AND dispatch both scale) by sleeping `(slowdown-1) x` the measured
    service time after each call — the router-imbalance phase uses it."""

    def __init__(self, inner: Model, latency_s: float, native: bool = True,
                 slowdown: float = 1.0):
        super().__init__(inner.name)
        self.inner = inner
        self.latency_s = latency_s
        self.slowdown = float(slowdown)
        self._inner_caps = model_capabilities(inner)
        self._native = native and self._inner_caps.evaluate_batch
        self.batch_bucket = getattr(inner, "batch_bucket", False)

    def get_input_sizes(self, c=None):
        return self.inner.get_input_sizes(c)

    def get_output_sizes(self, c=None):
        return self.inner.get_output_sizes(c)

    def capabilities(self, config=None):
        # forward the inner surface; the legacy-cluster emulation (native=
        # False) hides the batched variants, like a pre-extension server
        if self._native:
            return self._inner_caps
        from repro.core.interface import Capabilities

        return Capabilities(
            evaluate=True,
            gradient=self._inner_caps.gradient,
            apply_jacobian=self._inner_caps.apply_jacobian,
            apply_hessian=self._inner_caps.apply_hessian,
        )

    def __call__(self, p, c=None):
        t0 = time.monotonic()
        if self.latency_s:
            time.sleep(self.latency_s)
        out = self.inner(p, c)
        if self.slowdown > 1.0:
            time.sleep((self.slowdown - 1.0) * (time.monotonic() - t0))
        return out

    def evaluate_batch(self, thetas, config=None):
        if not self._native:  # legacy cluster: one round-trip per point
            return super().evaluate_batch(thetas, config)
        t0 = time.monotonic()
        if self.latency_s:
            time.sleep(self.latency_s)
        out = self.inner.evaluate_batch(thetas, config)
        if self.slowdown > 1.0:
            time.sleep((self.slowdown - 1.0) * (time.monotonic() - t0))
        return out

    def _timed_inner(self, call):
        t0 = time.monotonic()
        if self.latency_s:
            time.sleep(self.latency_s)
        out = call()
        if self.slowdown > 1.0:
            time.sleep((self.slowdown - 1.0) * (time.monotonic() - t0))
        return out

    def gradient_batch(self, thetas, senss, config=None):
        # one derivative wave = one cluster round-trip, like evaluate waves
        return self._timed_inner(
            lambda: self.inner.gradient_batch(thetas, senss, config)
        )

    def apply_jacobian_batch(self, thetas, vecs, config=None):
        return self._timed_inner(
            lambda: self.inner.apply_jacobian_batch(thetas, vecs, config)
        )

    def value_and_gradient_batch(self, thetas, sens_fn, config=None):
        return self._timed_inner(
            lambda: self.inner.value_and_gradient_batch(thetas, sens_fn, config)
        )


def build_hierarchy(n_gp_train: int = 128, seed: int = 3, cluster_latency_s: float = 0.0):
    model = TsunamiModel()
    # synthetic observations from the FINE model + noise
    rng = np.random.default_rng(seed)
    data = np.asarray(model([list(TRUE_THETA)], {"level": 1})[0])
    data = data + rng.standard_normal(4) * NOISE_SD * 0.5

    # GP emulator on low-discrepancy samples of the SMOOTHED model
    u = sobol(n_gp_train, 2, scramble_seed=seed)
    X = np.stack(
        [PRIOR[0][0] + u[:, 0] * (PRIOR[0][1] - PRIOR[0][0]),
         PRIOR[1][0] + u[:, 1] * (PRIOR[1][1] - PRIOR[1][0])], axis=1
    )
    t0 = time.monotonic()
    Y = np.array([model([list(x)], {"level": 0})[0] for x in X])
    t_train_evals = time.monotonic() - t0
    gps = [GP.fit(X, Y[:, j], n_iters=250) for j in range(4)]
    t_gp = time.monotonic() - t0 - t_train_evals

    def gp_logpost(theta):
        x0, A = float(theta[0]), float(theta[1])
        if not (PRIOR[0][0] <= x0 <= PRIOR[0][1] and PRIOR[1][0] <= A <= PRIOR[1][1]):
            return -np.inf
        obs = np.array([float(g.predict(np.array([[x0, A]]))[0]) for g in gps])
        return float(-0.5 * np.sum(((obs - data) / NOISE_SD) ** 2))

    def gp_logpost_batch(thetas):
        return np.asarray([gp_logpost(t) for t in np.atleast_2d(thetas)])

    # PDE levels flow through ONE EvaluationFabric: chains coalesce into
    # waves and MLDA's repeated coarse states hit the result cache instead
    # of the (emulated) cluster
    fabric = EvaluationFabric(
        ModelBackend(_RemoteModel(model, cluster_latency_s)), cache_size=8192
    )

    def logprior(theta):
        x0, A = float(theta[0]), float(theta[1])
        ok = PRIOR[0][0] <= x0 <= PRIOR[0][1] and PRIOR[1][0] <= A <= PRIOR[1][1]
        return 0.0 if ok else -np.inf

    def loglik(obs):
        return float(-0.5 * np.sum(((np.asarray(obs) - data) / NOISE_SD) ** 2))

    pde_logposts = fabric_logposts(
        fabric, loglik, [{"level": 0}, {"level": 1}], logprior=logprior
    )
    print(f"GP training: {n_gp_train} smoothed-model evals in {t_train_evals:.1f}s, "
          f"4 GP fits in {t_gp:.1f}s")
    return {
        "model": model,
        "logposts": [gp_logpost, *pde_logposts],
        "gp_logpost": gp_logpost,
        "gp_logpost_batch": gp_logpost_batch,
        "data": data,
        "fabric": fabric,
        "loglik": loglik,
        "logprior": logprior,
    }


def _ensemble_burnin(
    model: TsunamiModel,
    fabric: EvaluationFabric,
    data: np.ndarray,
    n_chains: int,
    n_burn: int,
    cluster_latency_s: float,
    prop_cov: np.ndarray,
) -> dict:
    """Lockstep ensemble burn-in on the SMOOTHED level: K chains advance with
    ONE `evaluate_batch` wave per step (one cluster round-trip, one vmapped
    SPMD solve), vs the 'before' discipline — K threaded chains against a
    legacy cluster without `/EvaluateBatch`: one round-trip AND one
    per-point solve per proposal, latencies overlapped across K single-
    tenant instances (the paper's HAProxy setup, fairest possible per-point
    baseline). Returns evals/sec, wave fill and round-trips for both, and
    the ensemble's final states (the MLDA chains start burned in)."""
    rng = np.random.default_rng(11)
    x0s = np.stack(
        [rng.uniform(*PRIOR[0], n_chains), rng.uniform(*PRIOR[1], n_chains)], axis=1
    )

    def logprior(th):
        ok = PRIOR[0][0] <= th[0] <= PRIOR[0][1] and PRIOR[1][0] <= th[1] <= PRIOR[1][1]
        return 0.0 if ok else -np.inf

    def loglik(obs):
        return float(-0.5 * np.sum(((np.asarray(obs) - data) / NOISE_SD) ** 2))

    # before: same chains, same smoothed level, per-point dispatch through
    # the repo's HAProxy analogue — each of K single-tenant instances holds
    # one request in flight, so cluster latencies overlap across chains (a
    # few calibration steps suffice to measure the rate)
    n_cal = 3
    pool = ThreadedPool(
        _RemoteModel(model, cluster_latency_s, native=False), n_instances=n_chains
    )

    def chain_pp(i):
        def lp(th):
            if not np.isfinite(logprior(th)):
                return -np.inf
            obs = pool.submit(th, {"level": 0}).result()
            return float(loglik(obs))

        return random_walk_metropolis(
            lp, x0s[i], n_cal, prop_cov, np.random.default_rng(300 + i)
        )

    t0 = time.monotonic()
    run_chains(chain_pp, n_chains, parallel=True)
    wall_pp = time.monotonic() - t0
    rt_pp = pool.stats["evaluations"]  # one round-trip per point
    rate_pp = rt_pp / wall_pp
    pool.shutdown()

    # after: the lockstep ensemble through the batch-native fabric; rate
    # counts points that actually reached the model (prior-masked proposals
    # don't)
    lp_batch = batched_logpost(fabric, loglik, logprior, {"level": 0})
    lp_batch(x0s)  # warm the batched jit path — the per-point baseline above
    lp_batch.reset()  # runs warm too (compiled during setup)
    t0 = time.monotonic()
    res = ensemble_random_walk_metropolis(lp_batch, x0s, n_burn, prop_cov, rng)
    wall_ls = time.monotonic() - t0
    rate_ls = lp_batch.points_evaluated / wall_ls

    out = {
        "n_chains": n_chains,
        "n_burn": n_burn,
        "threaded_evals_per_sec": round(rate_pp, 2),
        "ensemble_evals_per_sec": round(rate_ls, 2),
        "speedup": round(rate_ls / rate_pp, 2),
        "threaded_wave_fill": round(1.0 / n_chains, 3),  # 1 point/dispatch
        "ensemble_wave_fill": round(
            lp_batch.points_evaluated / (lp_batch.waves * n_chains), 3
        ),
        "round_trips_per_step_before": n_chains,
        "round_trips_per_step_after": 1,
        "accept_rate": round(res.accept_rate, 3),
    }
    print(f"smoothed-level burn-in, {n_chains} chains: per-point "
          f"{out['threaded_evals_per_sec']} evals/s (wave fill "
          f"{out['threaded_wave_fill']:.0%}, {n_chains} round-trips/step) -> "
          f"lockstep {out['ensemble_evals_per_sec']} evals/s (fill "
          f"{out['ensemble_wave_fill']:.0%}, 1 round-trip/step), "
          f"{out['speedup']}x")
    return {"stats": out, "final_states": res.samples[:, -1, :]}


def _ensemble_mlda_phase(
    h: dict,
    n_fine: int,
    subsampling,
    cluster_latency_s: float,
    prop_cov: np.ndarray,
    x0s: np.ndarray,
) -> dict:
    """Lockstep ensemble MLDA vs the per-point single-chain MLDA path, on
    the same host budget and the same (emulated) remote cluster: the single
    chain pays one cluster round-trip per subchain step, the K-chain
    ensemble turns each subchain step / acceptance test into ONE
    `evaluate_batch` wave — the paper's 1400-coarse/800-fine budget as ~tens
    of waves instead of thousands of round-trips."""
    model, loglik, logprior = h["model"], h["loglik"], h["logprior"]
    K = len(x0s)
    level_cfgs = [{"level": 0}, {"level": 1}]

    # before: ONE chain, per-point dispatch (the seed's only MLDA discipline)
    fab_pp = EvaluationFabric(
        ModelBackend(_RemoteModel(model, cluster_latency_s)), cache_size=8192
    )
    logposts_pp = [
        h["gp_logpost"],
        *fabric_logposts(fab_pp, loglik, level_cfgs, logprior=logprior),
    ]
    t0 = time.monotonic()
    res_pp = mlda(
        logposts_pp, x0s[0], n_fine, list(subsampling), prop_cov,
        np.random.default_rng(500),
    )
    wall_pp = time.monotonic() - t0
    evals_pp = int(np.sum(res_pp.evals_per_level))
    fab_pp.shutdown()

    # after: K chains in lockstep through the batch-native fabric
    fab_ls = EvaluationFabric(
        ModelBackend(_RemoteModel(model, cluster_latency_s)), cache_size=8192
    )
    lp_batches = [
        h["gp_logpost_batch"],
        *batched_level_logposts(fab_ls, loglik, level_cfgs, logprior=logprior),
    ]
    t0 = time.monotonic()
    res_ls = ensemble_mlda(
        lp_batches, x0s, n_fine, list(subsampling), prop_cov,
        np.random.default_rng(501),
    )
    wall_ls = time.monotonic() - t0
    evals_ls = int(np.sum(res_ls.evals_per_level))
    tel = fab_ls.telemetry()
    fab_ls.shutdown()

    rate_pp = evals_pp / wall_pp
    rate_ls = evals_ls / wall_ls
    out = {
        "n_chains": K,
        "n_fine_samples": n_fine,
        "single_chain_evals_per_sec": round(rate_pp, 2),
        "ensemble_evals_per_sec": round(rate_ls, 2),
        "speedup": round(rate_ls / rate_pp, 2),
        "single_chain_evals": evals_pp,
        "ensemble_evals": evals_ls,
        "ensemble_waves": res_ls.n_waves,
        "ensemble_wave_fill": round(tel["wave_fill"], 3),
        "ensemble_evals_per_level": res_ls.evals_per_level,
        "accept_rates": [round(r, 3) for r in res_ls.accept_rates],
    }
    print(f"ensemble MLDA, {K} lockstep chains x {n_fine} fine samples: "
          f"single-chain per-point {out['single_chain_evals_per_sec']} evals/s "
          f"-> ensemble {out['ensemble_evals_per_sec']} evals/s "
          f"({out['speedup']}x), {evals_ls} evals in {res_ls.n_waves} waves")
    return out


def _router_phase(
    model: TsunamiModel,
    cluster_latency_s: float,
    n_points: int = 16,
    n_waves: int = 4,
) -> dict:
    """Heterogeneous cluster: a fast sub-cluster and one 4x slower (the
    paper's uneven-resources case, cf. Loi/Wille/Reinarz). The same waves of
    coarse tsunami solves run under round-robin and latency-aware routing;
    report the imbalance factor (wave wall time over ideal balanced wall
    time) and throughput for both."""
    from benchmarks.weak_scaling import measure_router_policies

    lat = max(cluster_latency_s, 0.02)
    rng = np.random.default_rng(7)
    n_total = n_points * (n_waves + 2)
    thetas = np.stack(
        [rng.uniform(*PRIOR[0], n_total), rng.uniform(*PRIOR[1], n_total)],
        axis=1,
    )
    # the 2-core budget: two single-tenant sub-clusters, one on uniformly
    # 4x-slower (emulated) hardware
    out = measure_router_policies(
        lambda: [
            ThreadedPool(_RemoteModel(model, lat, native=False), n_instances=1),
            ThreadedPool(
                _RemoteModel(model, lat, native=False, slowdown=4.0),
                n_instances=1,
            ),
        ],
        thetas, n_points, n_waves, config={"level": 0},
    )
    print(f"router over [1x, 4x-slower] sub-clusters, {n_waves} waves x "
          f"{n_points} pts: round_robin imbalance "
          f"{out['round_robin']['imbalance']} "
          f"({out['round_robin']['evals_per_sec']} evals/s) -> latency-aware "
          f"{out['latency']['imbalance']} "
          f"({out['latency']['evals_per_sec']} evals/s, shares "
          f"{out['latency']['backend_share']})")
    return out


def run(
    n_chains: int = 8,
    n_fine_samples: int = 7,
    subsampling=(25, 2),
    n_gp_train: int = 128,
    cluster_latency_s: float = 0.0,
    n_burn: int = 12,
):
    # GP runs on the workstation; PDE levels are dispatched through the
    # fabric to an (emulated) remote cluster — latency-dominated from the UQ
    # process's perspective, so chains parallelize and cache hits are free
    h = build_hierarchy(n_gp_train, cluster_latency_s=cluster_latency_s)
    model, logposts, data, fabric = h["model"], h["logposts"], h["data"], h["fabric"]
    prop_cov = np.diag([8.0**2, 0.25**2])  # pre-tuned to the GP posterior scale

    # lockstep ensemble burn-in on the smoothed level: one batched wave per
    # step, and the MLDA chains below start from its final states
    ens = _ensemble_burnin(
        model, fabric, data, n_chains, n_burn, cluster_latency_s, prop_cov
    )
    x0s = ens["final_states"]

    t0 = time.monotonic()

    def chain(i):
        rng = np.random.default_rng(100 + i)
        return mlda(logposts, x0s[i], n_fine_samples, list(subsampling), prop_cov, rng)

    results = run_chains(chain, n_chains, parallel=True)
    wall = time.monotonic() - t0

    samples = np.concatenate([r.samples for r in results], axis=0)
    evals = np.sum([r.evals_per_level for r in results], axis=0)
    # sequential-equivalent time from per-level eval counts x measured costs
    t_coarse = _timed(lambda: model([list(TRUE_THETA)], {"level": 0})) + cluster_latency_s
    t_fine = _timed(lambda: model([list(TRUE_THETA)], {"level": 1})) + cluster_latency_s
    seq_equiv = evals[1] * t_coarse + evals[2] * t_fine
    speedup = seq_equiv / wall
    post_mean = samples.mean(0)
    chains_x = np.stack([r.samples[:, 0] for r in results])
    rhat = gelman_rubin(chains_x)
    fab = fabric.telemetry()
    fabric.shutdown()

    # tentpole phases: lockstep ensemble MLDA vs the per-point single chain,
    # and latency-aware routing over a deliberately uneven cluster
    K = max(8, n_chains)  # K >= 8 so wave amortization is visible even quick
    ens_mlda = _ensemble_mlda_phase(
        h, n_fine_samples, subsampling, cluster_latency_s,
        prop_cov, np.resize(x0s, (K, x0s.shape[1])),
    )
    router = _router_phase(model, cluster_latency_s)
    print(f"chains={n_chains} fine samples/chain={n_fine_samples} wall={wall:.1f}s")
    print(f"evals per level (GP, smoothed, fine): {evals.tolist()} "
          f"(paper: GP free, 1400 smoothed, 800 fine)")
    print(f"fabric: {fab['cache_hits']} cache hits / {fab['cache_misses']} misses "
          f"(hit rate {fab['cache_hit_rate']:.1%}) — duplicate coarse states "
          f"never reached the cluster")
    print(f"posterior mean theta=({post_mean[0]:.1f} km, {post_mean[1]:.2f} m) "
          f"true=({TRUE_THETA[0]}, {TRUE_THETA[1]}); R-hat(x0)={rhat:.2f}")
    print(f"speedup vs sequential-equivalent (parallel chains + cache): {speedup:.1f} "
          f"(paper: 96.38 from parallelism alone on 100 chains)")
    return {
        "wall_s": wall,
        "evals_per_level": evals.tolist(),
        "posterior_mean": post_mean.tolist(),
        "speedup": float(speedup),
        "rhat_x0": float(rhat),
        "cache_hit_rate": fab["cache_hit_rate"],
        "cache_hits": fab["cache_hits"],
        "ensemble": ens["stats"],
        "ensemble_mlda": ens_mlda,
        "router": router,
    }


def _timed(f):
    t0 = time.monotonic()
    f()
    return time.monotonic() - t0


def main(quick: bool = False):
    if quick:
        return run(n_chains=4, n_fine_samples=3, subsampling=(5, 2), n_gp_train=32,
                   cluster_latency_s=0.1, n_burn=6)
    return run(n_chains=16, n_fine_samples=7, subsampling=(25, 2), n_gp_train=128,
               cluster_latency_s=0.25, n_burn=12)


if __name__ == "__main__":
    main()
