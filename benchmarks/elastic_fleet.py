"""Elastic-fleet chaos benchmark: kill + enroll mid-campaign, speculative
re-dispatch, and crash-consistent checkpoint resume.

Three phases over the same sleepy two-level model:

1. **static ceiling** — `ensemble_mlda` on a healthy 3-backend fleet;
   evals/s here is the reference throughput.
2. **chaos** — same campaign, but one backend is a `FaultInjector`-wrapped
   straggler that is KILLED a third of the way in, while a `FleetManager`
   loop drains the corpse and a replacement node enrolls mid-run
   (`add_backend` — the operator plugging in a fresh pod). Speculative
   re-dispatch duplicates the straggler's late shards. The campaign must
   finish every wave (a lost wave raises) and sustain throughput within
   the configured fraction of the static ceiling.
3. **checkpoint** — the driver itself is killed mid-campaign
   (`StepFailure` out of the model); re-invoking with the same
   `CampaignCheckpoint` resumes and must reproduce the uninterrupted
   reference run EXACTLY (same rng stream), with posterior moments near
   the analytic values.

    PYTHONPATH=src python -m benchmarks.elastic_fleet [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.core.fabric import (
    CallableBackend,
    EvaluationFabric,
    FabricRouter,
    ThreadedBackend,
)
from repro.core.fleet import CampaignCheckpoint, FaultInjector, FleetManager
from repro.core.interface import Model
from repro.core.pool import ThreadedPool
from repro.distributed.fault import StepFailure
from repro.uq.mlda import ensemble_mlda


class _SleepLevelModel(Model):
    """Two-level quadratic with a per-call sleep: out = sum((theta-shift)^2),
    shift -0.5 on the coarse level and 1.0 on the fine level, so with
    loglik(y) = -y/2 the fine posterior is the analytic N(1, I)."""

    def __init__(self, cost_s: float):
        super().__init__("forward")
        self.cost_s = cost_s

    def get_input_sizes(self, c=None):
        return [2]

    def get_output_sizes(self, c=None):
        return [1]

    def supports_evaluate(self):
        return True

    def __call__(self, p, c=None):
        if self.cost_s:
            time.sleep(self.cost_s)
        shift = -0.5 if (c or {}).get("level") == 0 else 1.0
        th = np.asarray(p[0], float)
        return [[float(((th - shift) ** 2).sum())]]


def _campaign_kwargs(n_samples: int):
    return dict(
        n_samples=n_samples,
        subsampling=[4],
        loglik=lambda y: -0.5 * float(y[0]),
        level_configs=[{"level": 0}, {"level": 1}],
    )


def _run_campaign(fabric, n_samples: int, K: int = 8, seed: int = 42, **kw):
    kwargs = _campaign_kwargs(n_samples)
    kwargs.update(kw)
    rng = np.random.default_rng(seed)
    x0s = np.random.default_rng(7).standard_normal((K, 2)) * 0.3 + 1.0
    t0 = time.monotonic()
    res = ensemble_mlda(
        None, x0s, kwargs.pop("n_samples"), kwargs.pop("subsampling"),
        0.7 * np.eye(2), rng, fabric=fabric, **kwargs,
    )
    wall = time.monotonic() - t0
    return res, wall


def _mk_pool(cost_s: float, width: int = 2) -> ThreadedBackend:
    return ThreadedBackend(
        ThreadedPool([_SleepLevelModel(cost_s) for _ in range(width)])
    )


def main(quick: bool = True, smoke: bool = False) -> dict:
    n_samples = 16 if smoke else (36 if quick else 150)
    cost_s = 0.002 if smoke else 0.003
    # smoke runs on loaded CI runners; quick/full assert the paper-level bar
    min_ratio = 0.5 if smoke else 0.8

    # -- phase 1: static ceiling ---------------------------------------------
    router = FabricRouter([_mk_pool(cost_s) for _ in range(3)])
    fabric = EvaluationFabric(router, cache_size=4096)
    try:
        res_static, wall_static = _run_campaign(fabric, n_samples)
        static_points = fabric.stats["points"]
    finally:
        fabric.shutdown()
    static_rate = static_points / wall_static

    # -- phase 2: kill + enroll mid-run with speculation on -------------------
    # jittered straggler: typical delay folds into its EWMA, the tail draws
    # stall past spec_factor * EWMA and get speculatively duplicated
    straggler = FaultInjector(_mk_pool(cost_s), delay_s=(0.0, 8 * cost_s))
    router = FabricRouter(
        [_mk_pool(cost_s), _mk_pool(cost_s), straggler],
        backoff_s=0.05, spec_factor=1.3, spec_min_s=0.005,
    )
    fabric = EvaluationFabric(router, cache_size=4096)
    mgr = FleetManager(fabric, retire_streak=3)
    enrolled_at = []

    def enroll_replacement():
        fabric.add_backend(_mk_pool(cost_s))
        enrolled_at.append(time.monotonic())

    # the straggler dies a third of the way in; the replacement pod arrives
    # two thirds in — in between the fleet runs degraded (steals + backoff)
    t_kill = wall_static / 3.0
    killer = threading.Timer(t_kill, straggler.kill)
    joiner = threading.Timer(2 * t_kill, enroll_replacement)
    for t in (killer, joiner):
        t.daemon = True
        t.start()
    mgr.start(interval_s=0.05)
    try:
        res_chaos, wall_chaos = _run_campaign(fabric, n_samples)
        chaos_points = fabric.stats["points"]
        tel = router.stats()
        admin = router.admin_states()
    finally:
        mgr.stop()
        killer.cancel()
        joiner.cancel()
        fabric.shutdown()
    chaos_rate = chaos_points / wall_chaos
    ratio = chaos_rate / static_rate
    events = [e["event"] for e in mgr.events]

    # every wave completed (a lost wave raises out of ensemble_mlda) and the
    # chaos campaign samples the same posterior the static one does
    assert res_chaos.samples.shape == res_static.samples.shape
    fine_mean = float(res_chaos.samples[:, n_samples // 5:].mean())

    # -- phase 3: kill the DRIVER, resume from the campaign checkpoint --------
    n_ckpt = max(40, 2 * n_samples)
    every = max(5, n_ckpt // 8)
    waves = [0]
    kill_wave = [None]

    def model(thetas, config):
        waves[0] += 1
        if kill_wave[0] is not None and waves[0] > kill_wave[0]:
            raise StepFailure(f"driver killed at wave {waves[0]}")
        shift = -0.5 if (config or {}).get("level") == 0 else 1.0
        return ((np.asarray(thetas) - shift) ** 2).sum(1, keepdims=True)

    def fresh_fabric():
        waves[0] = 0
        kill_wave[0] = None
        return EvaluationFabric(CallableBackend(model), cache_size=4096)

    fab = fresh_fabric()
    try:
        ref, _ = _run_campaign(fab, n_ckpt)
        ref_waves = waves[0]
    finally:
        fab.shutdown()

    with tempfile.TemporaryDirectory() as d:
        ckpt = CampaignCheckpoint(d)
        fab = fresh_fabric()
        kill_wave[0] = ref_waves // 2
        crashed = False
        try:
            _run_campaign(fab, n_ckpt, checkpoint=ckpt, checkpoint_every=every)
        except StepFailure:
            crashed = True
        finally:
            fab.shutdown()
        assert crashed, "the driver kill never fired — raise kill_wave"
        resumed_from = ckpt.resume()[2]
        fab = fresh_fabric()
        try:
            res, _ = _run_campaign(fab, n_ckpt, checkpoint=ckpt,
                                   checkpoint_every=every)
            resumed_waves = waves[0]
        finally:
            fab.shutdown()
    exact = bool(np.array_equal(res.samples, ref.samples))
    assert exact, "resumed campaign diverged from the uninterrupted reference"
    # loose analytic-moment check (the tier-1 tests bound this properly via
    # the MC-error-aware harness; here it guards against gross bias only)
    burn = n_ckpt // 5
    post = res.samples[:, burn:].reshape(-1, 2)
    mean_err = float(np.abs(post.mean(0) - 1.0).max())
    var_err = float(np.abs(post.var(0) - 1.0).max())
    assert mean_err < 0.5 and var_err < 0.8, (
        f"resumed posterior far from N(1, I): mean_err={mean_err:.2f} "
        f"var_err={var_err:.2f}"
    )

    doc = {
        "schema": "elastic-fleet-v1",
        "created_unix": time.time(),
        "mode": "smoke" if smoke else ("quick" if quick else "full"),
        "static": {
            "evals_per_sec": round(static_rate, 1),
            "wall_s": round(wall_static, 3),
            "points": static_points,
        },
        "chaos": {
            "evals_per_sec": round(chaos_rate, 1),
            "wall_s": round(wall_chaos, 3),
            "points": chaos_points,
            "throughput_ratio": round(ratio, 3),
            "min_ratio": min_ratio,
            "waves_lost": 0,  # ensemble_mlda raised on none
            "kill_after_s": round(t_kill, 3),
            "replacement_enrolled": bool(enrolled_at),
            "fleet_admin_final": admin,
            "lifecycle_events": events,
            "steals": tel["steals"],
            "spec_dispatches": tel["spec_dispatches"],
            "spec_wins": tel["spec_wins"],
            "n_backends_final": tel["n_backends"],
            "fine_posterior_mean": round(fine_mean, 3),
        },
        "checkpoint": {
            "resumed_from_step": resumed_from,
            "checkpoint_every": every,
            "ref_waves": ref_waves,
            "resumed_waves": resumed_waves,
            "wave_savings": round(1.0 - resumed_waves / ref_waves, 3),
            "resume_exact": exact,
            "posterior_mean_err": round(mean_err, 4),
            "posterior_var_err": round(var_err, 4),
        },
    }
    print(
        f"elastic fleet: chaos throughput {chaos_rate:.0f}/s vs static "
        f"{static_rate:.0f}/s (ratio {ratio:.2f}, floor {min_ratio}), "
        f"{tel['steals']} steals, {tel['spec_dispatches']} speculative "
        f"dispatches ({tel['spec_wins']} wins), events {events}; resume "
        f"from step {resumed_from} exact={exact} "
        f"({doc['checkpoint']['wave_savings']:.0%} of waves saved)"
    )
    return doc


def _cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + loose throughput floor for CI")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the chaos telemetry document")
    args = ap.parse_args()
    doc = main(smoke=args.smoke)
    if args.json:
        # write BEFORE the gate checks: on failure the artifact is the
        # investigation's starting point
        Path(args.json).write_text(json.dumps(doc, indent=1))
        print(f"telemetry -> {args.json}")
    chaos = doc["chaos"]
    if not chaos["lifecycle_events"]:
        raise SystemExit(
            "chaos phase exercised no lifecycle event — the kill landed "
            "after the campaign finished; raise n_samples or lower t_kill"
        )
    if chaos["throughput_ratio"] < chaos["min_ratio"]:
        raise SystemExit(
            f"chaos throughput ratio {chaos['throughput_ratio']} below the "
            f"floor {chaos['min_ratio']}: the fleet did not absorb the "
            "kill+enroll churn"
        )


if __name__ == "__main__":
    _cli()
